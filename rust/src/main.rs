//! ecmac CLI — the leader entrypoint for the reproduction.
//!
//! Subcommands map to the paper's experiments (see DESIGN.md):
//!   info       artifact + model + area summary
//!   table1     Table I (multiplier error statistics)
//!   power      power sweep: Fig. 5 + Fig. 6 + Fig. 7 + CSV
//!   area       area roll-up vs the paper's 26084 um^2
//!   accuracy   test-set accuracy per configuration (native or PJRT)
//!   classify   one image through native + cycle-accurate + PJRT backends
//!   serve      synthetic-load serving demo with a governor policy
//!   loadgen    open/closed/bursty load harness: adaptive vs batch=1
//!              throughput/latency/energy per policy -> BENCH_serve.json
//!   sweep      native accuracy sweep: uniform configs or per-layer sensitivity
//!   frontier   per-layer schedule frontier from the sensitivity model
//!   topo       topology-parametric demo: arbitrary MLP + per-layer schedule
//!   bench      in-process benchmarks (--cycle-batch -> BENCH_cycle_batch.json,
//!              --forward -> BENCH_forward.json before/after comparison,
//!              --pipeline -> BENCH_pipeline.json stage-pipelined vs
//!              row-partitioned)
//!   chaos      deterministic fault-injection campaign across the serve
//!              stack: every class must end masked, detected+degraded, or
//!              failed-fast -> CHAOS.json
//!   sentinel   online accuracy-audit campaign: every class must end clean
//!              or detected+recovered -> SENTINEL.json

use anyhow::{Context, Result};
use ecmac::amul::{metrics, Config, ConfigSchedule};
use ecmac::coordinator::governor::{AccuracyTable, Policy};
use ecmac::coordinator::loadgen::{run_load, LoadMode, LoadReport, LoadSpec};
use ecmac::coordinator::{
    Backend, Coordinator, CoordinatorConfig, ExecutionMode, Governor, MetricsSnapshot,
    NativeBackend, PjrtBackend, ScheduleFrontier, SensitivityModel, TcpIntake,
};
use ecmac::dataset::Dataset;
use ecmac::datapath::{DatapathSim, Network};
use ecmac::power::{MultiplierEnergyProfile, PowerModel};
use ecmac::report;
use ecmac::util::cli::{Args, OptSpec};
use ecmac::weights::{QuantWeights, Topology};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().map(|s| s.as_str()) else {
        print_global_usage();
        std::process::exit(2);
    };
    let rest = &argv[1..];
    let result = match cmd {
        "info" => cmd_info(rest),
        "table1" => cmd_table1(rest),
        "power" => cmd_power(rest),
        "area" => cmd_area(rest),
        "accuracy" => cmd_accuracy(rest),
        "classify" => cmd_classify(rest),
        "serve" => cmd_serve(rest),
        "loadgen" => cmd_loadgen(rest),
        "sweep" => cmd_sweep(rest),
        "frontier" => cmd_frontier(rest),
        "topo" => cmd_topo(rest),
        "bench" => cmd_bench(rest),
        "analyze" => cmd_analyze(rest),
        "chaos" => cmd_chaos(rest),
        "sentinel" => cmd_sentinel(rest),
        "ablation" => cmd_ablation(rest),
        "verilog" => cmd_verilog(rest),
        "--help" | "-h" | "help" => {
            print_global_usage();
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n");
            print_global_usage();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_global_usage() {
    println!(
        "ecmac — dynamic power control in a hardware MLP with error-configurable MAC units\n\n\
         commands:\n\
         \x20 info       artifact + model + area summary\n\
         \x20 table1     Table I: multiplier error statistics\n\
         \x20 power      power sweep (Fig. 5/6/7 + CSV)\n\
         \x20 area       area roll-up\n\
         \x20 accuracy   per-configuration test accuracy\n\
         \x20 classify   one image through all backends\n\
         \x20 serve      serving demo with a governor policy (--listen for TCP intake)\n\
         \x20 loadgen    load harness: adaptive vs batch=1 curves per policy\n\
         \x20            (open/closed/burst modes -> BENCH_serve.json)\n\
         \x20 sweep      native accuracy sweep (uniform, or --per-layer sensitivity)\n\
         \x20 frontier   per-layer schedule frontier (Pareto energy vs accuracy)\n\
         \x20 topo       arbitrary-topology demo with a per-layer schedule\n\
         \x20 bench      in-process benchmarks (--cycle-batch: per-image vs interleaved;\n\
         \x20            --forward: tiled SIMD GEMM + prefix-cached sweep before/after)\n\
         \x20 analyze    static verification: datapath value ranges, pipeline-plan\n\
         \x20            liveness, protocol model checking (-> ANALYZE.json)\n\
         \x20 chaos      deterministic fault-injection campaign: table/accumulator\n\
         \x20            SEUs, stage stalls + panics, flaky backends, dropped\n\
         \x20            connections -> CHAOS.json\n\
         \x20 sentinel   online accuracy-audit campaign: shadow-sampling estimate\n\
         \x20            cross-check, silent drift, mid-serve table corruption,\n\
         \x20            ladder re-promotion -> SENTINEL.json\n\
         \x20 ablation   heterogeneous per-neuron configuration study\n\
         \x20 verilog    export the EC multiplier as synthesizable Verilog\n"
    );
}

fn common_opts() -> Vec<OptSpec> {
    vec![OptSpec {
        name: "artifacts",
        help: "artifacts directory (default: $ECMAC_ARTIFACTS or ./artifacts)",
        takes_value: true,
        default: None,
    }]
}

fn artifacts_dir(args: &Args) -> PathBuf {
    args.get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(ecmac::runtime::default_artifacts_dir)
}

/// Build the calibrated power model; uses real operand traces from the
/// test set when `trace_images > 0`, synthetic streams otherwise.
fn power_model(artifacts: &PathBuf, trace_images: usize) -> Result<PowerModel> {
    let profile = if trace_images > 0 {
        let ds = Dataset::load_test(artifacts)?;
        let weights = QuantWeights::load_artifacts(artifacts)?;
        let net = Network::new(weights);
        let n = trace_images.min(ds.len());
        // capture per-neuron operand traces with the cycle-accurate sim
        struct Tracer {
            traces: Vec<Vec<(u32, u32)>>,
        }
        impl ecmac::datapath::MacObserver for Tracer {
            fn on_mac(&mut self, neuron: usize, x: u8, w: u8) {
                self.traces[neuron].push(((x & 0x7F) as u32, (w & 0x7F) as u32));
            }
        }
        let mut tracer = Tracer {
            traces: vec![Vec::new(); 10],
        };
        let mut sim = DatapathSim::new(&net, Config::ACCURATE);
        for i in 0..n {
            sim.run_image_observed(&ds.features[i], &mut tracer);
        }
        MultiplierEnergyProfile::measure_traces(&tracer.traces)
    } else {
        MultiplierEnergyProfile::measure_synthetic(4000, 0xD1E5E1)
    };
    Ok(PowerModel::calibrate(profile)?)
}

fn cmd_info(argv: &[String]) -> Result<()> {
    let spec = common_opts();
    let args = Args::parse(argv, &spec)?;
    let dir = artifacts_dir(&args);
    println!("artifacts: {}", dir.display());
    let weights = QuantWeights::load_artifacts(&dir)?;
    let topo = weights.topology.clone();
    println!(
        "network: {topo} MLP ({} weight layers, {} parameters), 10 physical neurons",
        topo.n_layers(),
        weights
            .layers
            .iter()
            .map(|l| l.w.len() + l.b.len())
            .sum::<usize>()
    );
    let ds = Dataset::load_test(&dir)?;
    println!("test set: {} images, {} features each", ds.len(), topo.inputs());
    println!(
        "cycles/image: {} ({:.2} us at 100 MHz)",
        topo.cycles_per_image(),
        topo.cycles_per_image() as f64 / 100.0
    );
    println!(
        "area: {:.0} um2 (paper: {:.0} um2)",
        ecmac::power::area::total_area_um2(),
        ecmac::power::area::PAPER_AREA_UM2
    );
    println!(
        "timing: MAC critical path {:.2} ns -> fmax {:.0} MHz (paper: 100-330 MHz)",
        ecmac::power::area::timing::mac_critical_path_ps() / 1000.0,
        ecmac::power::area::timing::fmax_mhz()
    );
    match ecmac::runtime::Engine::load(&dir) {
        Ok(engine) => println!("pjrt: compiled batch sizes {:?}", engine.batch_sizes()),
        Err(e) => println!("pjrt: not available ({e})"),
    }
    Ok(())
}

fn cmd_table1(argv: &[String]) -> Result<()> {
    let mut spec = common_opts();
    spec.push(OptSpec {
        name: "csv",
        help: "write per-config CSV to this path",
        takes_value: true,
        default: None,
    });
    let args = Args::parse(argv, &spec)?;
    let stats = metrics::full_table();
    let summary = metrics::table_i(&stats);
    println!("{}", report::table_i(&stats, &summary));
    if let Some(path) = args.get("csv") {
        let mut t = report::TextTable::new(&["cfg", "er_pct", "mred_pct", "nmed_pct", "max_ed"]);
        for s in &stats {
            t.row(vec![
                s.cfg.to_string(),
                format!("{:.6}", s.er_pct),
                format!("{:.6}", s.mred_pct),
                format!("{:.6}", s.nmed_pct),
                s.max_ed.to_string(),
            ]);
        }
        std::fs::write(path, t.to_csv())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_power(argv: &[String]) -> Result<()> {
    let mut spec = common_opts();
    spec.push(OptSpec {
        name: "trace-images",
        help: "calibrate on operand traces from N test images (0 = synthetic stream)",
        takes_value: true,
        default: Some("64"),
    });
    spec.push(OptSpec {
        name: "csv",
        help: "write the sweep CSV to this path",
        takes_value: true,
        default: None,
    });
    let args = Args::parse(argv, &spec)?;
    let dir = artifacts_dir(&args);
    let trace_images: usize = args.get_or("trace-images", 64)?;
    let pm = power_model(&dir, trace_images)?;
    let sweep = pm.sweep();
    let acc = AccuracyTable::load(&dir.join("accuracy_sweep.json"))
        .map(|t| t.accuracy)
        .unwrap_or_else(|_| vec![f64::NAN; ecmac::amul::N_CONFIGS]);
    println!("{}", report::fig5_power_improvement(&sweep));
    println!("{}", report::fig6_power_accuracy(&sweep, &acc));
    println!("{}", report::fig7_tradeoff(&sweep, &acc));
    if let Some(path) = args.get("csv") {
        std::fs::write(path, report::sweep_csv(&sweep, &acc, &pm))?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_area(argv: &[String]) -> Result<()> {
    let spec = common_opts();
    let _ = Args::parse(argv, &spec)?;
    println!("{}", report::area_table());
    Ok(())
}

fn cmd_accuracy(argv: &[String]) -> Result<()> {
    let mut spec = common_opts();
    spec.push(OptSpec {
        name: "backend",
        help: "native | pjrt | cycle",
        takes_value: true,
        default: Some("native"),
    });
    spec.push(OptSpec {
        name: "configs",
        help: "'all' or comma-separated config list",
        takes_value: true,
        default: Some("all"),
    });
    spec.push(OptSpec {
        name: "limit",
        help: "evaluate at most N test images (0 = all)",
        takes_value: true,
        default: Some("0"),
    });
    spec.push(OptSpec {
        name: "schedule",
        help: "measure per-layer schedules instead (';'-separated, e.g. '32,0;0,32'); \
               schedules share one accurate-prefix checkpoint, and the sensitivity \
               model's prediction is printed when schedule_sweep.json exists",
        takes_value: true,
        default: None,
    });
    let args = Args::parse(argv, &spec)?;
    let dir = artifacts_dir(&args);
    let ds = Dataset::load_test(&dir)?;
    let limit: usize = args.get_or("limit", 0)?;
    let n = if limit == 0 { ds.len() } else { limit.min(ds.len()) };
    if let Some(s) = args.get("schedule") {
        let scheds: Vec<ConfigSchedule> = s
            .split(';')
            .filter(|t| !t.is_empty())
            .map(ConfigSchedule::parse)
            .collect::<Result<_>>()?;
        anyhow::ensure!(!scheds.is_empty(), "empty --schedule list");
        let net = Network::new(QuantWeights::load_artifacts(&dir)?);
        for sched in &scheds {
            sched.validate(net.topology().n_layers())?;
        }
        // all schedules measured off one accurate-prefix checkpoint
        let accs = net.accuracy_sched_many(&ds.features[..n], &ds.labels[..n], &scheds);
        let sweep = dir.join("schedule_sweep.json");
        let sens = if sweep.exists() {
            match SensitivityModel::load(&sweep) {
                Ok(sens) if sens.matches(net.topology()) => Some(sens),
                Ok(sens) => {
                    println!(
                        "(schedule_sweep.json covers topology {:?}, not this network — \
                         re-run `ecmac sweep --per-layer`)",
                        sens.sizes()
                    );
                    None
                }
                Err(e) => {
                    eprintln!("warning: cannot read {}: {e:#}", sweep.display());
                    None
                }
            }
        } else {
            println!("(no schedule_sweep.json for predictions)");
            None
        };
        for (sched, &acc) in scheds.iter().zip(&accs) {
            println!(
                "schedule {sched} on {n} test images: measured accuracy {:.2}%",
                acc * 100.0
            );
            if let Some(sens) = &sens {
                println!(
                    "  predicted (additive sensitivity model): {:.2}%  (delta {:+.3} pp)",
                    sens.predict(sched) * 100.0,
                    (sens.predict(sched) - acc) * 100.0
                );
            }
        }
        return Ok(());
    }
    let configs: Vec<Config> = match args.get("configs") {
        Some("all") | None => Config::all().collect(),
        Some(list) => list
            .split(',')
            .map(|s| {
                s.trim()
                    .parse::<u32>()
                    .ok()
                    .and_then(Config::new)
                    .with_context(|| format!("bad config '{s}'"))
            })
            .collect::<Result<_>>()?,
    };
    let backend = args.get("backend").unwrap_or("native").to_string();
    let features = &ds.features[..n];
    let labels = &ds.labels[..n];

    let mut t = report::TextTable::new(&["cfg", "accuracy %", "correct", "images"]);
    match backend.as_str() {
        "native" => {
            let net = Network::new(QuantWeights::load_artifacts(&dir)?);
            // parallel over configs
            let accs = ecmac::util::threadpool::par_map(&configs, |_, &cfg| {
                net.accuracy(features, labels, cfg)
            });
            for (cfg, acc) in configs.iter().zip(accs) {
                t.row(vec![
                    cfg.index().to_string(),
                    format!("{:.2}", acc * 100.0),
                    format!("{:.0}", acc * n as f64),
                    n.to_string(),
                ]);
            }
        }
        "pjrt" => {
            let engine = ecmac::runtime::Engine::load(&dir)?;
            for &cfg in &configs {
                let out = engine.execute(features, cfg)?;
                let correct = out
                    .preds
                    .iter()
                    .zip(labels)
                    .filter(|(p, l)| p == l)
                    .count();
                t.row(vec![
                    cfg.index().to_string(),
                    format!("{:.2}", correct as f64 / n as f64 * 100.0),
                    correct.to_string(),
                    n.to_string(),
                ]);
            }
        }
        "cycle" => {
            let net = Network::new(QuantWeights::load_artifacts(&dir)?);
            for &cfg in &configs {
                let mut sim = DatapathSim::new(&net, cfg);
                let correct = features
                    .iter()
                    .zip(labels)
                    .filter(|(x, &l)| sim.run_image(x).pred == l)
                    .count();
                t.row(vec![
                    cfg.index().to_string(),
                    format!("{:.2}", correct as f64 / n as f64 * 100.0),
                    correct.to_string(),
                    n.to_string(),
                ]);
            }
        }
        other => anyhow::bail!("unknown backend '{other}'"),
    }
    println!(
        "accuracy on {n} test images via {backend} backend\n\
         (paper: 89.67% accurate, 88.75% worst, 89.11% avg)\n"
    );
    println!("{}", t.render());
    Ok(())
}

fn cmd_classify(argv: &[String]) -> Result<()> {
    let mut spec = common_opts();
    spec.push(OptSpec {
        name: "index",
        help: "test-set image index",
        takes_value: true,
        default: Some("0"),
    });
    spec.push(OptSpec {
        name: "cfg",
        help: "multiplier configuration (0..32)",
        takes_value: true,
        default: Some("0"),
    });
    spec.push(OptSpec {
        name: "schedule",
        help: "per-layer schedule, e.g. '32,0' (overrides --cfg)",
        takes_value: true,
        default: None,
    });
    let args = Args::parse(argv, &spec)?;
    let dir = artifacts_dir(&args);
    let idx: usize = args.get_or("index", 0)?;
    let sched = match args.get("schedule") {
        Some(s) => ConfigSchedule::parse(s)?,
        None => ConfigSchedule::uniform(
            Config::new(args.get_or("cfg", 0u32)?).context("cfg must be 0..=32")?,
        ),
    };
    let ds = Dataset::load_test(&dir)?;
    anyhow::ensure!(idx < ds.len(), "index {idx} out of range ({})", ds.len());
    let x = &ds.features[idx];
    let label = ds.labels[idx];
    let net = Network::new(QuantWeights::load_artifacts(&dir)?);
    sched.validate(net.topology().n_layers())?;

    let fast = net.forward_sched(x, &sched);
    println!("image {idx} (label {label}), {sched}");
    println!("  native:          pred {}  logits {:?}", fast.pred, fast.logits);
    let mut sim = DatapathSim::new_scheduled(&net, sched.clone());
    let slow = sim.run_image(x);
    println!(
        "  cycle-accurate:  pred {}  ({} cycles)  match={}",
        slow.pred,
        sim.stats.cycles,
        slow == fast
    );
    match sched.as_uniform() {
        Some(cfg) => match ecmac::runtime::Engine::load(&dir) {
            Ok(engine) => {
                let out = engine.execute(std::slice::from_ref(x), cfg)?;
                println!(
                    "  pjrt (AOT jax):  pred {}  logits {:?}  match={}",
                    out.preds[0],
                    out.logits[0],
                    out.logits[0] == fast.logits
                );
            }
            Err(e) => println!("  pjrt: unavailable ({e})"),
        },
        None => println!("  pjrt: skipped (per-layer schedules run on the native fallback)"),
    }
    Ok(())
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    let mut spec = common_opts();
    spec.push(OptSpec {
        name: "policy",
        help: "fixed:<cfg> | sched:<cfg,cfg,..> | budget:<mw> | floor:<accuracy> | energy:<mj>:<images>",
        takes_value: true,
        default: Some("budget:5.0"),
    });
    spec.push(OptSpec {
        name: "requests",
        help: "number of synthetic requests",
        takes_value: true,
        default: Some("2000"),
    });
    spec.push(OptSpec {
        name: "rate",
        help: "arrival rate, requests/second (poisson)",
        takes_value: true,
        default: Some("20000"),
    });
    spec.push(OptSpec {
        name: "backend",
        help: "native | pjrt",
        takes_value: true,
        default: Some("native"),
    });
    spec.push(OptSpec {
        name: "max-batch",
        help: "maximum batch size",
        takes_value: true,
        default: Some("16"),
    });
    spec.push(OptSpec {
        name: "shards",
        help: "sub-batches per logical batch on the worker shard pool",
        takes_value: true,
        default: Some("2"),
    });
    spec.push(OptSpec {
        name: "slo",
        help: "latency objective for the adaptive batching window, us",
        takes_value: true,
        default: Some("5000"),
    });
    spec.push(OptSpec {
        name: "pipeline",
        help: "execute large batches through the stage-pipelined datapath \
               instead of the row-sharded pool",
        takes_value: false,
        default: None,
    });
    spec.push(OptSpec {
        name: "fixed-batch",
        help: "disable the adaptive window (pin the target at max-batch)",
        takes_value: false,
        default: None,
    });
    spec.push(OptSpec {
        name: "listen",
        help: "also serve framed requests over TCP on this address \
               (e.g. 127.0.0.1:7878)",
        takes_value: true,
        default: None,
    });
    spec.push(OptSpec {
        name: "deadline-ms",
        help: "per-request deadline: admitted requests older than this get a \
               resolved Deadline reply instead of occupying a batch (0 = off)",
        takes_value: true,
        default: Some("0"),
    });
    spec.push(OptSpec {
        name: "guardbands",
        help: "run the runtime envelope guardbands: windows whose accumulators \
               leave the static envelope fail loudly and step the governor \
               toward accurate",
        takes_value: false,
        default: None,
    });
    spec.push(OptSpec {
        name: "watchdog-ms",
        help: "pipeline watchdog: fail a stage-pipelined batch that makes no \
               end-to-end progress for this long (0 = off)",
        takes_value: true,
        default: Some("0"),
    });
    spec.push(OptSpec {
        name: "sweep",
        help: "schedule_sweep.json enabling the per-layer schedule frontier \
               (default: <artifacts>/schedule_sweep.json when present; 'none' disables)",
        takes_value: true,
        default: None,
    });
    spec.push(OptSpec {
        name: "shadow-rate",
        help: "accuracy sentinel: shadow re-execute 1-in-N served requests in \
               accurate mode off the hot path (0 = off); enables the sentinel",
        takes_value: true,
        default: Some("0"),
    });
    spec.push(OptSpec {
        name: "accuracy-slo",
        help: "tolerated approximate-vs-accurate disagreement rate; a confident \
               (Wilson lower bound) breach of it steps the governor toward \
               accurate; enables the sentinel",
        takes_value: true,
        default: None,
    });
    spec.push(OptSpec {
        name: "scrub-every",
        help: "sentinel table-scrub cadence in batch windows (default 32 when \
               the sentinel is enabled); passing it enables the sentinel",
        takes_value: true,
        default: None,
    });
    let args = Args::parse(argv, &spec)?;
    let dir = artifacts_dir(&args);
    let n_requests: usize = args.get_or("requests", 2000)?;
    let rate: f64 = args.get_or("rate", 20000.0)?;
    let max_batch: usize = args.get_or("max-batch", 16)?;
    let shards: usize = args.get_or("shards", 2)?;

    let pm = power_model(&dir, 32)?;
    let acc_table = AccuracyTable::load(&dir.join("accuracy_sweep.json"))?;
    let policy = parse_policy(args.get("policy").unwrap_or("budget:5.0"))?;

    let backend: Arc<dyn Backend> = match args.get("backend").unwrap_or("native") {
        "native" => Arc::new(NativeBackend {
            network: Network::new(QuantWeights::load_artifacts(&dir)?),
        }),
        "pjrt" => Arc::new(PjrtBackend::spawn(dir.clone())?),
        other => anyhow::bail!("unknown backend '{other}'"),
    };
    let backend_name = backend.name();
    if let Policy::FixedSchedule(s) = &policy {
        s.validate(backend.topology().n_layers())?;
    }
    // an explicitly named sweep must load; an auto-discovered one that
    // is stale or malformed only costs the frontier, not serving
    let (sweep_path, sweep_explicit) = match args.get("sweep") {
        Some("none") => (None, false),
        Some(p) => (Some(PathBuf::from(p)), true),
        None => {
            let p = dir.join("schedule_sweep.json");
            (p.exists().then_some(p), false)
        }
    };
    let uniform_governor =
        |policy: &Policy| Governor::for_topology(policy.clone(), &pm, &acc_table, backend.topology());
    let governor = match sweep_path {
        Some(p) => {
            let sensitivity_governor = SensitivityModel::load(&p).and_then(|sens| {
                Governor::with_sensitivity(
                    policy.clone(),
                    &pm,
                    &acc_table,
                    &sens,
                    backend.topology(),
                )
            });
            match sensitivity_governor {
                Ok(g) => {
                    println!("schedule frontier: enabled from {}", p.display());
                    g
                }
                Err(e) if !sweep_explicit => {
                    eprintln!(
                        "warning: ignoring {} ({e:#}); serving with the uniform frontier",
                        p.display()
                    );
                    uniform_governor(&policy)
                }
                Err(e) => return Err(e),
            }
        }
        None => uniform_governor(&policy),
    };

    let slo_us: u64 = args.get_or("slo", 5000)?;
    let deadline_ms: u64 = args.get_or("deadline-ms", 0)?;
    let watchdog_ms: u64 = args.get_or("watchdog-ms", 0)?;
    if watchdog_ms > 0 {
        ecmac::datapath::pipeline::set_watchdog(Some(Duration::from_millis(watchdog_ms)));
    }
    let shadow_rate: u32 = args.get_or("shadow-rate", 0)?;
    let accuracy_slo: Option<f64> = match args.get("accuracy-slo") {
        Some(s) => Some(s.parse().context("parsing --accuracy-slo")?),
        None => None,
    };
    let scrub_every: u64 = args.get_or("scrub-every", 32)?;
    let sentinel_on =
        shadow_rate > 0 || accuracy_slo.is_some() || args.get("scrub-every").is_some();
    let sentinel = sentinel_on.then(|| {
        // offline cross-check: the AccuracyTable's predicted
        // disagreement for the starting schedule (accurate-mode
        // accuracy minus schedule accuracy), when the schedule is
        // uniform
        let predicted = governor.current().as_uniform().map(|cfg| {
            (acc_table.get(Config::ACCURATE) - acc_table.get(cfg)).max(0.0)
        });
        ecmac::sentinel::SentinelConfig {
            shadow_rate,
            accuracy_slo,
            scrub_every,
            predicted_disagreement: predicted,
            ..ecmac::sentinel::SentinelConfig::default()
        }
    });
    if let Some(sc) = &sentinel {
        println!(
            "accuracy sentinel: shadow 1-in-{} (slo {:?}), scrub every {} windows",
            sc.shadow_rate, sc.accuracy_slo, sc.scrub_every
        );
    }
    let coord = Arc::new(Coordinator::start(
        CoordinatorConfig {
            max_batch,
            max_wait: Duration::from_micros(300),
            queue_capacity: 4096,
            workers: 2,
            shards,
            adaptive: !args.flag("fixed-batch"),
            latency_slo_us: slo_us,
            execution: if args.flag("pipeline") {
                ExecutionMode::Pipelined
            } else {
                ExecutionMode::RowSharded
            },
            deadline: (deadline_ms > 0).then(|| Duration::from_millis(deadline_ms)),
            guardbands: args.flag("guardbands"),
            sentinel,
            ..CoordinatorConfig::default()
        },
        backend,
        governor,
        pm.clone(),
    ));
    let mut intake = match args.get("listen") {
        Some(addr) => {
            let intake = TcpIntake::bind(addr, Arc::clone(&coord))?;
            println!("tcp intake listening on {}", intake.local_addr());
            Some(intake)
        }
        None => None,
    };

    let ds = Dataset::load_test(&dir)?;
    let mut rng = ecmac::util::rng::Pcg32::new(7);
    println!(
        "serving {n_requests} requests at ~{rate:.0}/s via {backend_name} backend, policy {policy:?}"
    );
    let t0 = std::time::Instant::now();
    let mut replies = Vec::with_capacity(n_requests);
    let mut true_labels = Vec::with_capacity(n_requests);
    for _ in 0..n_requests {
        let i = rng.below(ds.len() as u32) as usize;
        true_labels.push(ds.labels[i]);
        // poisson arrivals
        let gap = rng.exponential(rate);
        if gap > 1e-6 {
            std::thread::sleep(Duration::from_secs_f64(gap.min(0.01)));
        }
        match coord.try_submit(ds.features[i]) {
            Some(r) => replies.push(Some(r)),
            None => replies.push(None),
        }
    }
    let mut correct = 0u64;
    let mut answered = 0u64;
    for (r, label) in replies.into_iter().zip(true_labels) {
        if let Some(r) = r {
            if let Some(resp) = r.recv() {
                answered += 1;
                if resp.pred == label {
                    correct += 1;
                }
            }
        }
    }
    let wall = t0.elapsed();
    let decisions = coord.decisions();
    let sentinel_est = coord.sentinel().map(|s| s.estimate());
    if let Some(intake) = intake.as_mut() {
        intake.stop();
    }
    drop(intake);
    let m = Arc::try_unwrap(coord)
        .map_err(|_| anyhow::anyhow!("intake still holds the coordinator"))?
        .shutdown();
    println!("\n=== serving summary ===");
    println!("wall time          {:.3} s", wall.as_secs_f64());
    println!(
        "answered           {answered} / {n_requests} (rejected {})",
        m.rejected
    );
    if m.backend_errors > 0 {
        println!("backend errors     {} batches", m.backend_errors);
    }
    println!(
        "resilience         {} deadline-expired / {} envelope violations / \
         {} degradations / {} watchdog trips",
        m.deadline_expired, m.envelope_violations, m.degradations, m.watchdog_trips
    );
    if let Some(est) = sentinel_est {
        println!(
            "sentinel           {} shadow samples / {} disagreements / {} breaches / \
             {} scrubs / {} quarantines / {} probe failures / {} repromotions",
            m.shadow_samples,
            m.disagreements,
            m.accuracy_breaches,
            m.scrubs,
            m.quarantines,
            m.probe_failures,
            m.repromotions
        );
        if est.samples > 0 {
            let predicted = est
                .predicted
                .map(|p| format!("{p:.4}"))
                .unwrap_or_else(|| "n/a".into());
            println!(
                "disagreement       observed {:.4} (Wilson [{:.4}, {:.4}], n={}) \
                 vs offline predicted {predicted}",
                est.rate, est.lower, est.upper, est.samples
            );
        }
    }
    println!(
        "accuracy           {:.2}%",
        correct as f64 / answered.max(1) as f64 * 100.0
    );
    println!(
        "throughput         {:.0} img/s",
        answered as f64 / wall.as_secs_f64()
    );
    println!("latency mean       {:.0} us", m.mean_latency_us);
    println!(
        "latency p50/p95/p99  {} / {} / {} us (max {})",
        m.p50_latency_us, m.p95_latency_us, m.p99_latency_us, m.max_latency_us
    );
    println!(
        "mean batch         {:.2} (p50 {} / p95 {}, final target {})",
        m.mean_batch_size, m.batch_size_p50, m.batch_size_p95, m.batch_target
    );
    println!("batch size dist    {:?}", m.batch_size_dist);
    println!(
        "windows            {} closed full / {} on deadline",
        m.windows_full, m.windows_deadline
    );
    println!("modeled energy     {:.3} mJ", m.energy_mj);
    let used: Vec<(usize, u64)> = m
        .per_cfg
        .iter()
        .enumerate()
        .filter(|(_, &c)| c > 0)
        .map(|(i, &c)| (i, c))
        .collect();
    println!("configs used       {used:?}");
    if m.mixed > 0 {
        println!("per-layer served   {} requests", m.mixed);
    }
    let decided: Vec<String> = decisions
        .iter()
        .map(|(at, s)| format!("@{at}->{s}"))
        .collect();
    println!("governor decisions {decided:?}");
    Ok(())
}

/// Closed-loop/open-loop/bursty load harness: for each governor policy,
/// drive the same offered load through the adaptive-window front-end
/// and through a pinned batch=1 front-end, and publish the
/// throughput/latency/energy comparison (`BENCH_serve.json` with
/// `--json`).  `--synthetic` swaps artifacts for a deterministic random
/// network + synthetic calibration, so CI can smoke the serve path
/// without the seed artifacts.
fn cmd_loadgen(argv: &[String]) -> Result<()> {
    let mut spec = common_opts();
    spec.push(OptSpec {
        name: "policies",
        help: "comma-separated governor policies to sweep \
               (fixed:<cfg> | sched:<cfg,..> | budget:<mw> | floor:<acc> | energy:<mj>:<images>)",
        takes_value: true,
        default: Some("fixed:0,fixed:16,budget:5.0"),
    });
    spec.push(OptSpec {
        name: "mode",
        help: "traffic shape: closed | open | burst",
        takes_value: true,
        default: Some("closed"),
    });
    spec.push(OptSpec {
        name: "concurrency",
        help: "closed-loop client count",
        takes_value: true,
        default: Some("8"),
    });
    spec.push(OptSpec {
        name: "rate",
        help: "open-loop offered rate (burst: the high rate), req/s",
        takes_value: true,
        default: Some("20000"),
    });
    spec.push(OptSpec {
        name: "low-rate",
        help: "burst mode low rate, req/s",
        takes_value: true,
        default: Some("2000"),
    });
    spec.push(OptSpec {
        name: "period-ms",
        help: "burst mode phase length, ms",
        takes_value: true,
        default: Some("20"),
    });
    spec.push(OptSpec {
        name: "requests",
        help: "requests offered per run",
        takes_value: true,
        default: Some("4000"),
    });
    spec.push(OptSpec {
        name: "max-batch",
        help: "adaptive window ceiling (the baseline run always pins 1)",
        takes_value: true,
        default: Some("64"),
    });
    spec.push(OptSpec {
        name: "workers",
        help: "executor worker threads",
        takes_value: true,
        default: Some("2"),
    });
    spec.push(OptSpec {
        name: "shards",
        help: "sub-batches per logical batch on the worker shard pool",
        takes_value: true,
        default: Some("2"),
    });
    spec.push(OptSpec {
        name: "slo",
        help: "adaptive window latency objective, us (high = maximize throughput)",
        takes_value: true,
        default: Some("50000"),
    });
    spec.push(OptSpec {
        name: "seed",
        help: "arrival-process / input-selection seed",
        takes_value: true,
        default: Some("42"),
    });
    spec.push(OptSpec {
        name: "json",
        help: "write the per-policy curve as a BENCH_serve.json artifact",
        takes_value: true,
        default: None,
    });
    spec.push(OptSpec {
        name: "synthetic",
        help: "use a deterministic random seed-topology network instead of artifacts",
        takes_value: false,
        default: None,
    });
    spec.push(OptSpec {
        name: "topology",
        help: "synthetic network topology, e.g. 62x128x64x10 \
               (requires --synthetic; first dim must be 62, the wire feature width)",
        takes_value: true,
        default: None,
    });
    spec.push(OptSpec {
        name: "pipeline",
        help: "execute large batches through the stage-pipelined datapath \
               instead of the row-sharded pool",
        takes_value: false,
        default: None,
    });
    spec.push(OptSpec {
        name: "chaos-flaky",
        help: "fault smoke: fail every nth backend window, exercising the \
               degradation ladder under load (0 = off)",
        takes_value: true,
        default: Some("0"),
    });
    spec.push(OptSpec {
        name: "wire",
        help: "drive the closed loop through the TCP intake with retrying \
               clients (closed mode only; counts RETRY backoffs and \
               Deadline replies)",
        takes_value: false,
        default: None,
    });
    spec.push(OptSpec {
        name: "shadow-rate",
        help: "sentinel shadow-audit 1-in-N sampling under load (0 = off); \
               measures the audit overhead on the serve curve",
        takes_value: true,
        default: Some("0"),
    });
    let args = Args::parse(argv, &spec)?;
    let requests: usize = args.get_or("requests", 4000)?;
    let max_batch: usize = args.get_or("max-batch", 64)?;
    let workers: usize = args.get_or("workers", 2)?;
    let shards: usize = args.get_or("shards", 2)?;
    let slo_us: u64 = args.get_or("slo", 50000)?;
    let seed: u64 = args.get_or("seed", 42)?;
    let mode = match args.get("mode").unwrap_or("closed") {
        "closed" => LoadMode::Closed {
            concurrency: args.get_or("concurrency", 8)?,
        },
        "open" => LoadMode::Open {
            rate_rps: args.get_or("rate", 20000.0)?,
        },
        "burst" => LoadMode::Burst {
            high_rps: args.get_or("rate", 20000.0)?,
            low_rps: args.get_or("low-rate", 2000.0)?,
            period: Duration::from_millis(args.get_or("period-ms", 20)?),
        },
        other => anyhow::bail!("unknown mode '{other}' (closed | open | burst)"),
    };
    let flaky_every: u64 = args.get_or("chaos-flaky", 0)?;
    let shadow_rate: u32 = args.get_or("shadow-rate", 0)?;
    anyhow::ensure!(
        !args.flag("wire") || matches!(mode, LoadMode::Closed { .. }),
        "--wire drives closed-loop clients only (use --mode closed)"
    );

    anyhow::ensure!(
        args.get("topology").is_none() || args.flag("synthetic"),
        "--topology only applies to --synthetic runs (artifact weights fix the topology)"
    );
    let (weights, acc_table, pm, inputs) = if args.flag("synthetic") {
        let topo = match args.get("topology") {
            Some(spec) => {
                let t = Topology::parse(spec)?;
                anyhow::ensure!(
                    t.inputs() == ecmac::dataset::N_FEATURES,
                    "--topology must take {} inputs (the wire feature width), got {}",
                    ecmac::dataset::N_FEATURES,
                    t.inputs()
                );
                t
            }
            None => Topology::seed(),
        };
        let weights = QuantWeights::random(&topo, 11);
        let pm = PowerModel::calibrate(MultiplierEnergyProfile::measure_synthetic(
            2000, 0xD1E5E1,
        ))?;
        let acc_table = AccuracyTable::new(
            // mildly decreasing so floor/budget policies have a real
            // trade-off to walk, like the measured sweep does
            (0..ecmac::amul::N_CONFIGS)
                .map(|c| 0.95 - 0.002 * c as f64)
                .collect(),
        );
        let mut rng = ecmac::util::rng::Pcg32::new(seed);
        let inputs: Vec<[u8; 62]> = (0..256)
            .map(|_| {
                let mut x = [0u8; 62];
                for v in x.iter_mut() {
                    *v = rng.below(128) as u8;
                }
                x
            })
            .collect();
        (weights, acc_table, pm, inputs)
    } else {
        let dir = artifacts_dir(&args);
        let weights = QuantWeights::load_artifacts(&dir)?;
        let pm = power_model(&dir, 32)?;
        let acc_table = AccuracyTable::load(&dir.join("accuracy_sweep.json"))?;
        let ds = Dataset::load_test(&dir)?;
        let inputs: Vec<[u8; 62]> = ds.features.iter().take(1024).copied().collect();
        (weights, acc_table, pm, inputs)
    };

    let policies_arg = args.get("policies").unwrap_or("fixed:0,fixed:16,budget:5.0");
    let mut rows_json: Vec<ecmac::util::json::Json> = Vec::new();
    let mut table_rows: Vec<report::ServeBenchRow> = Vec::new();
    for pol_s in policies_arg.split(',') {
        let policy = parse_policy(pol_s.trim())?;
        // one fresh coordinator per (policy, front-end) run, same
        // offered load: the only variable is the batching strategy
        let run = |adaptive: bool, run_max_batch: usize| -> Result<(LoadReport, MetricsSnapshot)> {
            let native: Arc<dyn Backend> = Arc::new(NativeBackend {
                network: Network::new(weights.clone()),
            });
            let backend: Arc<dyn Backend> = if flaky_every > 0 {
                Arc::new(ecmac::testkit::doubles::FlakyBackend::wrap(
                    native,
                    flaky_every,
                ))
            } else {
                native
            };
            if let Policy::FixedSchedule(s) = &policy {
                s.validate(backend.topology().n_layers())?;
            }
            let gov =
                Governor::for_topology(policy.clone(), &pm, &acc_table, backend.topology());
            let coord = Coordinator::start(
                CoordinatorConfig {
                    max_batch: run_max_batch,
                    max_wait: Duration::from_micros(300),
                    queue_capacity: 4096,
                    workers,
                    shards,
                    adaptive,
                    latency_slo_us: slo_us,
                    execution: if args.flag("pipeline") {
                        ExecutionMode::Pipelined
                    } else {
                        ExecutionMode::RowSharded
                    },
                    sentinel: (shadow_rate > 0).then(|| ecmac::sentinel::SentinelConfig {
                        shadow_rate,
                        ..ecmac::sentinel::SentinelConfig::default()
                    }),
                    ..CoordinatorConfig::default()
                },
                backend,
                gov,
                pm.clone(),
            );
            let spec = LoadSpec {
                mode: mode.clone(),
                requests,
                seed,
            };
            if args.flag("wire") {
                let coord = Arc::new(coord);
                let mut intake =
                    TcpIntake::bind("127.0.0.1:0", Arc::clone(&coord))?;
                let r = ecmac::coordinator::run_wire_closed(
                    intake.local_addr(),
                    &inputs,
                    &spec,
                    Duration::from_secs(2),
                )?;
                intake.stop();
                drop(intake);
                let m = Arc::try_unwrap(coord)
                    .map_err(|_| anyhow::anyhow!("intake still holds the coordinator"))?
                    .shutdown();
                Ok((r, m))
            } else {
                let r = run_load(&coord, &inputs, &spec);
                let m = coord.shutdown();
                Ok((r, m))
            }
        };
        let (base_r, base_m) = run(false, 1)?;
        let (adap_r, adap_m) = run(true, max_batch)?;
        let policy_label = policy.to_string();
        println!(
            "{policy_label} [{}]: batch1 {:.0} req/s -> adaptive {:.0} req/s ({:.2}x), \
             p99 {} us, mean batch {:.2}",
            adap_r.mode,
            base_r.throughput_rps,
            adap_r.throughput_rps,
            adap_r.throughput_rps / base_r.throughput_rps.max(1e-9),
            adap_r.p99_us,
            adap_m.mean_batch_size,
        );
        if flaky_every > 0 || args.flag("wire") {
            println!(
                "  resilience: {} errors / {} deadline / {} wire retries / \
                 {} degradations / {} backend-error windows",
                adap_r.errors,
                adap_r.deadline,
                adap_r.retries,
                adap_m.degradations,
                adap_m.backend_errors
            );
        }
        if shadow_rate > 0 {
            println!(
                "  sentinel: {} shadow samples / {} disagreements",
                adap_m.shadow_samples, adap_m.disagreements
            );
        }
        let energy_nj = adap_m.energy_mj * 1e6 / adap_r.answered.max(1) as f64;
        let base_energy_nj = base_m.energy_mj * 1e6 / base_r.answered.max(1) as f64;
        rows_json.push(ecmac::json_obj! {
            "policy" => policy_label.clone(),
            "mode" => adap_r.mode.clone(),
            "offered_rps" => adap_r.offered_rps,
            "batch1_throughput_rps" => base_r.throughput_rps,
            "throughput_rps" => adap_r.throughput_rps,
            "adaptive_speedup" => adap_r.throughput_rps / base_r.throughput_rps.max(1e-9),
            "p50_us" => adap_r.p50_us as f64,
            "p95_us" => adap_r.p95_us as f64,
            "p99_us" => adap_r.p99_us as f64,
            "batch1_p99_us" => base_r.p99_us as f64,
            "mean_batch" => adap_m.mean_batch_size,
            "batch_target" => adap_m.batch_target,
            "energy_per_image_nj" => energy_nj,
            "batch1_energy_per_image_nj" => base_energy_nj,
            "answered" => adap_r.answered as f64,
            "rejected" => adap_r.rejected as f64,
            "errors" => adap_r.errors as f64,
            "deadline" => adap_r.deadline as f64,
            "retries" => adap_r.retries as f64,
            "degradations" => adap_m.degradations as f64,
            "windows_full" => adap_m.windows_full as f64,
            "windows_deadline" => adap_m.windows_deadline as f64,
        });
        table_rows.push(report::ServeBenchRow {
            policy: policy_label,
            mode: adap_r.mode.clone(),
            offered_rps: adap_r.offered_rps,
            batch1_rps: base_r.throughput_rps,
            adaptive_rps: adap_r.throughput_rps,
            p50_us: adap_r.p50_us,
            p95_us: adap_r.p95_us,
            p99_us: adap_r.p99_us,
            mean_batch: adap_m.mean_batch_size,
            energy_nj_per_img: energy_nj,
            rejected: adap_r.rejected,
        });
    }
    println!("\nadaptive window vs fixed batch=1 at equal offered load:");
    println!("{}", report::serve_bench_table(&table_rows));
    if let Some(path) = args.get("json") {
        let doc = ecmac::json_obj! {
            "schema_version" => 1usize,
            "bench" => "serve",
            "requests" => requests,
            "max_batch" => max_batch,
            "workers" => workers,
            "shards" => shards,
            "slo_us" => slo_us as f64,
            "synthetic" => args.flag("synthetic"),
            "topology" => args.get("topology").unwrap_or("seed").to_string(),
            "pipeline" => args.flag("pipeline"),
            "rows" => rows_json,
        };
        std::fs::write(path, doc.to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}

/// Native accuracy sweep over the test set.  Default: the uniform
/// 33-configuration sweep (the python pipeline's `accuracy_sweep.json`,
/// regenerated without python).  With `--per-layer`: the sensitivity
/// sweep — one layer approximated at a time — written as the versioned
/// `schedule_sweep.json` the frontier search and `serve` consume.
fn cmd_sweep(argv: &[String]) -> Result<()> {
    let mut spec = common_opts();
    spec.push(OptSpec {
        name: "per-layer",
        help: "sweep one layer at a time into schedule_sweep.json \
               (default: uniform sweep into accuracy_sweep.json)",
        takes_value: false,
        default: None,
    });
    spec.push(OptSpec {
        name: "limit",
        help: "evaluate at most N test images (0 = all)",
        takes_value: true,
        default: Some("0"),
    });
    spec.push(OptSpec {
        name: "out",
        help: "output path (default: <artifacts>/schedule_sweep.json or accuracy_sweep.json)",
        takes_value: true,
        default: None,
    });
    let args = Args::parse(argv, &spec)?;
    let dir = artifacts_dir(&args);
    let ds = Dataset::load_test(&dir)?;
    let limit: usize = args.get_or("limit", 0)?;
    let n = if limit == 0 { ds.len() } else { limit.min(ds.len()) };
    let net = Network::new(QuantWeights::load_artifacts(&dir)?);
    let features = &ds.features[..n];
    let labels = &ds.labels[..n];
    if args.flag("per-layer") {
        // per-job progress on stderr: long sweeps on big evaluation
        // sets (32·L suffix passes) stay observable
        let jobs_total = 32 * net.topology().n_layers();
        eprintln!(
            "per-layer sweep: {jobs_total} jobs over {n} images \
             (accurate prefix checkpointed once)"
        );
        let t0 = std::time::Instant::now();
        let progress = |p: ecmac::coordinator::SweepProgress| {
            eprintln!(
                "  job {:>3}/{}: layer {} {} in {:.1} ms",
                p.done, p.total, p.layer, p.cfg, p.job_ms
            );
        };
        let sens = SensitivityModel::measure_with_progress(&net, features, labels, Some(&progress));
        eprintln!(
            "sweep finished: {jobs_total} jobs in {:.2} s",
            t0.elapsed().as_secs_f64()
        );
        let out = args
            .get("out")
            .map(PathBuf::from)
            .unwrap_or_else(|| dir.join("schedule_sweep.json"));
        sens.save(&out)?;
        println!("{}", report::sensitivity_table(net.topology(), &sens));
        println!("wrote {}", out.display());
    } else {
        let configs: Vec<Config> = Config::all().collect();
        let accs = ecmac::util::threadpool::par_map(&configs, |_, &cfg| {
            net.accuracy(features, labels, cfg)
        });
        let rows: Vec<ecmac::util::json::Json> = configs
            .iter()
            .zip(&accs)
            .map(|(cfg, &acc)| ecmac::json_obj! { "cfg" => cfg.index(), "accuracy" => acc })
            .collect();
        let out = args
            .get("out")
            .map(PathBuf::from)
            .unwrap_or_else(|| dir.join("accuracy_sweep.json"));
        std::fs::write(&out, ecmac::util::json::Json::from(rows).to_string())?;
        let worst = accs[1..].iter().cloned().fold(f64::MAX, f64::min);
        println!(
            "uniform accuracy sweep over {n} images: accurate {:.2}%, worst approx {:.2}%",
            accs[0] * 100.0,
            worst * 100.0
        );
        println!("wrote {}", out.display());
    }
    Ok(())
}

/// Build and print the per-layer schedule frontier: Pareto-optimal
/// `ConfigSchedule`s ranked by modeled energy per image vs predicted
/// accuracy, from a `schedule_sweep.json` artifact (or an on-the-fly
/// sensitivity sweep when the artifact is absent).
fn cmd_frontier(argv: &[String]) -> Result<()> {
    let mut spec = common_opts();
    spec.push(OptSpec {
        name: "sweep",
        help: "schedule_sweep.json path (default: <artifacts>/schedule_sweep.json; \
               measured on the fly when absent; 'none' forces measurement)",
        takes_value: true,
        default: None,
    });
    spec.push(OptSpec {
        name: "limit",
        help: "images for an on-the-fly sensitivity sweep and for \
               --validate measurements (0 = all)",
        takes_value: true,
        default: Some("2000"),
    });
    spec.push(OptSpec {
        name: "beam",
        help: "beam width of the pruned frontier search",
        takes_value: true,
        default: Some("128"),
    });
    spec.push(OptSpec {
        name: "budget",
        help: "also print the frontier point a power budget (mW) selects",
        takes_value: true,
        default: None,
    });
    spec.push(OptSpec {
        name: "floor",
        help: "also print the frontier point an accuracy floor selects, \
               next to the cheapest uniform config meeting it",
        takes_value: true,
        default: None,
    });
    spec.push(OptSpec {
        name: "csv",
        help: "write the frontier as CSV to this path",
        takes_value: true,
        default: None,
    });
    spec.push(OptSpec {
        name: "validate",
        help: "measure the K most accurate frontier points on the test set \
               (accurate prefixes share one checkpoint) and print measured \
               vs predicted accuracy — the additive-assumption check",
        takes_value: true,
        default: Some("0"),
    });
    let args = Args::parse(argv, &spec)?;
    let dir = artifacts_dir(&args);
    let weights = QuantWeights::load_artifacts(&dir)?;
    let topo = weights.topology.clone();
    // an explicitly named sweep must exist; 'none' (as in `serve`)
    // forces the on-the-fly measurement, and only the default artifacts
    // path falls back to it when absent
    let forced_measure = args.get("sweep") == Some("none");
    let explicit = match args.get("sweep") {
        None | Some("none") => None,
        Some(p) => Some(PathBuf::from(p)),
    };
    let sweep_path = explicit
        .clone()
        .unwrap_or_else(|| dir.join("schedule_sweep.json"));
    // loaded at most once, shared by the on-the-fly sweep and --validate
    let mut dataset: Option<Dataset> = None;
    let sens = if explicit.is_some() || (!forced_measure && sweep_path.exists()) {
        let s = SensitivityModel::load(&sweep_path)?;
        println!(
            "sensitivity: {} ({} images)\n",
            sweep_path.display(),
            s.images()
        );
        s
    } else {
        let ds = dataset.get_or_insert(Dataset::load_test(&dir)?);
        let limit: usize = args.get_or("limit", 2000)?;
        let n = if limit == 0 { ds.len() } else { limit.min(ds.len()) };
        println!(
            "sensitivity: no {} — measuring on {n} test images\n",
            sweep_path.display()
        );
        let net = Network::new(weights.clone());
        SensitivityModel::measure(&net, &ds.features[..n], &ds.labels[..n])
    };
    anyhow::ensure!(
        sens.matches(&topo),
        "schedule sweep covers topology {:?} but the artifacts serve {topo} \
         (re-run `ecmac sweep --per-layer`)",
        sens.sizes()
    );
    let pm = power_model(&dir, 32)?;
    let beam: usize = args.get_or("beam", 128)?;
    let frontier = ScheduleFrontier::search(&pm, &sens, &topo, beam);
    println!("{}", report::sensitivity_table(&topo, &sens));
    println!("{}", report::frontier_table(&frontier));
    // the uniform knob's frontier (measured accuracies), for contrast;
    // a missing sweep skips quietly, a malformed one is worth a warning
    let acc_sweep = dir.join("accuracy_sweep.json");
    if acc_sweep.exists() {
        match AccuracyTable::load(&acc_sweep) {
            Ok(table) => {
                let uni = ScheduleFrontier::uniform(&pm, &table, &topo);
                println!(
                    "uniform frontier (measured accuracy_sweep.json): {} of 33 configs are Pareto",
                    uni.len()
                );
                println!("{}", report::frontier_table(&uni));
            }
            Err(e) => eprintln!("warning: skipping uniform contrast ({e:#})"),
        }
    }
    if let Some(path) = args.get("csv") {
        std::fs::write(path, report::frontier_csv(&frontier))?;
        println!("wrote {path}");
    }
    if let Some(b) = args.get("budget") {
        let budget: f64 = b.parse().context("--budget must be a number (mW)")?;
        match frontier.best_under_power(budget) {
            Some(p) => println!(
                "power budget {budget} mW -> {} ({:.3} mW, {:.3} nJ/img, predicted {:.2}%)",
                p.sched,
                p.power_mw,
                p.energy_nj,
                p.accuracy * 100.0
            ),
            None => println!("power budget {budget} mW -> no frontier point fits"),
        }
    }
    if let Some(fl) = args.get("floor") {
        let floor: f64 = fl.parse().context("--floor must be a number in [0, 1]")?;
        match frontier.cheapest_meeting(floor) {
            Some(p) => {
                println!(
                    "accuracy floor {floor} -> {} ({:.3} nJ/img, predicted {:.2}%)",
                    p.sched,
                    p.energy_nj,
                    p.accuracy * 100.0
                );
                // the uniform knob's answer to the same floor, for contrast
                let uni = Config::all()
                    .map(ConfigSchedule::uniform)
                    .filter(|s| sens.predict(s) >= floor)
                    .min_by(|a, b| {
                        pm.energy_per_image_nj_sched(&topo, a)
                            .partial_cmp(&pm.energy_per_image_nj_sched(&topo, b))
                            .unwrap()
                    });
                match uni {
                    Some(u) => {
                        let e = pm.energy_per_image_nj_sched(&topo, &u);
                        println!(
                            "  cheapest uniform meeting the floor: {u} ({e:.3} nJ/img, \
                             schedule saves {:.2}%)",
                            (e - p.energy_nj) / e * 100.0
                        );
                    }
                    None => println!("  no uniform configuration meets the floor"),
                }
            }
            None => println!("accuracy floor {floor} -> unreachable on this frontier"),
        }
    }
    let validate: usize = args.get_or("validate", 0)?;
    if validate > 0 {
        let ds = match dataset.take() {
            Some(ds) => ds,
            None => Dataset::load_test(&dir)?,
        };
        let limit: usize = args.get_or("limit", 2000)?;
        let n = if limit == 0 { ds.len() } else { limit.min(ds.len()) };
        let points: Vec<&ecmac::coordinator::SchedulePoint> =
            frontier.points().iter().rev().take(validate).collect();
        let scheds: Vec<ConfigSchedule> = points.iter().map(|p| p.sched.clone()).collect();
        let net = Network::new(weights.clone());
        let measured = net.accuracy_sched_many(&ds.features[..n], &ds.labels[..n], &scheds);
        println!(
            "\nfrontier validation: {} most accurate points measured on {n} test images",
            points.len()
        );
        println!("{}", report::frontier_validation_table(&points, &measured));
        let worst = points
            .iter()
            .zip(&measured)
            .map(|(p, &m)| (p.accuracy - m).abs())
            .fold(0.0, f64::max);
        println!(
            "largest |measured - predicted| gap: {:.3} pp \
             (additive-degradation assumption check)",
            worst * 100.0
        );
    }
    Ok(())
}

/// Topology-parametric demo: build a pseudo-random network of an
/// arbitrary topology, prove the three execution paths agree under a
/// per-layer schedule, and report the schedule's cycle/power split plus
/// the batched-vs-per-image throughput win.
fn cmd_topo(argv: &[String]) -> Result<()> {
    let spec = vec![
        OptSpec {
            name: "topology",
            help: "comma-separated layer sizes, e.g. 62,20,20,10",
            takes_value: true,
            default: Some("62,30,10"),
        },
        OptSpec {
            name: "schedule",
            help: "uniform cfg ('9') or per-layer list ('32,16,0')",
            takes_value: true,
            default: Some("0"),
        },
        OptSpec {
            name: "images",
            help: "random images to run",
            takes_value: true,
            default: Some("512"),
        },
        OptSpec {
            name: "seed",
            help: "weight/input PRNG seed",
            takes_value: true,
            default: Some("7"),
        },
    ];
    let args = Args::parse(argv, &spec)?;
    let topo = Topology::parse(args.get("topology").unwrap_or("62,30,10"))?;
    let sched = ConfigSchedule::parse(args.get("schedule").unwrap_or("0"))?;
    sched.validate(topo.n_layers())?;
    let n: usize = args.get_or("images", 512)?;
    let seed: u64 = args.get_or("seed", 7)?;

    let net = Network::new(QuantWeights::random(&topo, seed));
    let mut rng = ecmac::util::rng::Pcg32::new(seed ^ 0x5EED);
    let xs: Vec<Vec<u8>> = (0..n.max(1))
        .map(|_| (0..topo.inputs()).map(|_| rng.below(128) as u8).collect())
        .collect();

    println!("topology {topo}: {} weight layers, {} cycles/image, schedule {sched}\n",
        topo.n_layers(),
        topo.cycles_per_image()
    );

    // three-path parity on a subset
    let batch = net.forward_batch(&xs, &sched);
    let mut sim = DatapathSim::new_scheduled(&net, sched.clone());
    let check_n = xs.len().min(16);
    let mut parity = true;
    for (x, r) in xs.iter().zip(&batch).take(check_n) {
        parity &= *r == net.forward_sched(x, &sched) && *r == sim.run_image(x);
    }
    println!("functional / batched / cycle-accurate parity on {check_n} images: {parity}");
    anyhow::ensure!(parity, "execution paths diverged");

    // per-image vs batched layer-major throughput (tables prewarmed so
    // the timed region never pays lazy init)
    net.tables.prewarm(&sched);
    let t0 = std::time::Instant::now();
    for x in &xs {
        std::hint::black_box(net.forward_sched(x, &sched));
    }
    let per_image = t0.elapsed();
    let t0 = std::time::Instant::now();
    std::hint::black_box(net.forward_batch(&xs, &sched));
    let batched = t0.elapsed();
    println!(
        "throughput ({} images): per-image {:.0} img/s, batched layer-major {:.0} img/s \
         ({:.2}x)\n",
        xs.len(),
        xs.len() as f64 / per_image.as_secs_f64(),
        xs.len() as f64 / batched.as_secs_f64(),
        per_image.as_secs_f64() / batched.as_secs_f64()
    );

    let pm = PowerModel::calibrate(MultiplierEnergyProfile::measure_synthetic(2000, 0xD1E5E1))?;
    println!("{}", report::schedule_summary(&topo, &sched, &pm));
    Ok(())
}

/// In-process benchmark driver.  `--cycle-batch` compares the per-image
/// cycle-accurate FSM against the interleaved batch schedule across a
/// set of topologies and writes `BENCH_cycle_batch.json`; `--forward`
/// compares the tiled-kernel GEMM functional path (and
/// the prefix-cached sweep engine) against the pre-PR reference paths
/// and writes `BENCH_forward.json`.  Both verify bit-exactness before
/// timing; CI records the artifacts for the perf trajectory.
fn cmd_bench(argv: &[String]) -> Result<()> {
    let spec = vec![
        OptSpec {
            name: "cycle-batch",
            help: "per-image vs interleaved cycle-accurate batch comparison",
            takes_value: false,
            default: None,
        },
        OptSpec {
            name: "forward",
            help: "tiled SIMD GEMM + prefix-cached sweep vs the reference paths",
            takes_value: false,
            default: None,
        },
        OptSpec {
            name: "pipeline",
            help: "stage-pipelined deep-topology batch vs the row-partitioned path",
            takes_value: false,
            default: None,
        },
        OptSpec {
            name: "batch",
            help: "images per batch",
            takes_value: true,
            default: Some("64"),
        },
        OptSpec {
            name: "topologies",
            help: "semicolon-separated topology specs to compare \
                   (default: mode-specific set)",
            takes_value: true,
            default: None,
        },
        OptSpec {
            name: "sweep-images",
            help: "evaluation-set size for the --forward sweep comparison",
            takes_value: true,
            default: Some("64"),
        },
        OptSpec {
            name: "kernel",
            help: "pin the --forward GEMM kernel: auto | scalar | avx2 \
                   (default: runtime dispatch)",
            takes_value: true,
            default: Some("auto"),
        },
        OptSpec {
            name: "par-batch",
            help: "images for the --forward multi-core row-partitioned bench \
                   (0 disables it) and for the --pipeline comparison",
            takes_value: true,
            default: Some("512"),
        },
        OptSpec {
            name: "json",
            help: "write the comparison artifact to this path",
            takes_value: true,
            default: None,
        },
        OptSpec {
            name: "quick",
            help: "shorter measurement for smoke runs",
            takes_value: false,
            default: None,
        },
    ];
    let args = Args::parse(argv, &spec)?;
    let modes = [args.flag("cycle-batch"), args.flag("forward"), args.flag("pipeline")];
    anyhow::ensure!(
        modes.iter().filter(|&&f| f).count() == 1,
        "pass exactly one of --cycle-batch / --forward / --pipeline \
         (the full suite lives in `cargo bench`)"
    );
    let batch: usize = args.get_or("batch", 64)?;
    anyhow::ensure!(batch >= 1, "--batch must be at least 1");

    use ecmac::testkit::bench::{BenchConfig, Bencher};
    let quick = args.flag("quick");
    let bench_cfg = BenchConfig {
        warmup: Duration::from_millis(if quick { 20 } else { 100 }),
        measure: Duration::from_millis(if quick { 120 } else { 600 }),
        samples: if quick { 4 } else { 10 },
        filter: None,
        json_out: None,
    };
    if args.flag("forward") {
        return bench_forward(&args, bench_cfg, batch);
    }
    if args.flag("pipeline") {
        return bench_pipeline(&args, bench_cfg);
    }
    let specs: Vec<&str> = args
        .get("topologies")
        .unwrap_or("62,30,10;8,23,5;4,4,3;62,33,10")
        .split(';')
        .filter(|s| !s.is_empty())
        .collect();
    let mut b = Bencher::new(bench_cfg);
    let sched = ConfigSchedule::uniform(Config::new(9).unwrap());
    let mut rows: Vec<ecmac::util::json::Json> = Vec::new();
    let mut table_rows: Vec<report::CycleBatchRow> = Vec::new();
    for spec_s in &specs {
        let topo = Topology::parse(spec_s)?;
        // registers the timed pair and asserts bit-exactness first:
        // the comparison is meaningless otherwise
        let interleaved = ecmac::testkit::bench_cycle_batch_pair(&mut b, &topo, batch, &sched);
        let per_image_name = format!("cycle_batch/per_image_{topo}");
        let interleaved_name = format!("cycle_batch/interleaved_{topo}");

        let sequential_cycles = batch as u64 * topo.cycles_per_image();
        let batch_cycles = topo.batch_cycles(batch as u64);
        anyhow::ensure!(
            interleaved.cycles == batch_cycles,
            "{topo}: simulated cycles {} diverge from the cycle model {batch_cycles}",
            interleaved.cycles
        );
        let per_image_ns = b.result(&per_image_name).map(|r| r.mean_ns).unwrap_or(-1.0);
        let interleaved_ns = b.result(&interleaved_name).map(|r| r.mean_ns).unwrap_or(-1.0);
        rows.push(ecmac::json_obj! {
            "topology" => topo.to_string(),
            "cycles_per_image" => topo.cycles_per_image() as f64,
            "sequential_cycles" => sequential_cycles as f64,
            "batch_cycles" => batch_cycles as f64,
            "cycle_speedup" => sequential_cycles as f64 / batch_cycles as f64,
            "has_partial_pass" => topo.has_partial_pass(),
            "extra_wsel_asserts" => interleaved.extra_wsel_asserts as f64,
            "per_image_mean_ns" => per_image_ns,
            "interleaved_mean_ns" => interleaved_ns,
            "wall_speedup" => per_image_ns / interleaved_ns.max(1e-9),
            "bit_exact" => true,
        });
        table_rows.push(report::CycleBatchRow {
            topology: topo.to_string(),
            batch: batch as u64,
            sequential_cycles,
            batch_cycles,
            extra_wsel: interleaved.extra_wsel_asserts,
        });
    }
    // full harness stats for every registered bench, alongside the
    // per-topology comparison rows
    let harness_rows: Vec<ecmac::util::json::Json> = b
        .results()
        .iter()
        .map(|r| {
            ecmac::json_obj! {
                "name" => r.name.clone(),
                "mean_ns" => r.mean_ns,
                "median_ns" => r.median_ns,
                "p95_ns" => r.p95_ns,
                "throughput_per_sec" => r.throughput_per_sec().unwrap_or(-1.0),
            }
        })
        .collect();
    b.finish();
    println!("\ncycle model (per-image FSM x batch vs interleaved batch schedule):");
    println!("{}", report::cycle_batch_table(&table_rows));
    if let Some(path) = args.get("json") {
        let doc = ecmac::json_obj! {
            "schema_version" => 1usize,
            "bench" => "cycle_batch",
            "batch" => batch,
            "rows" => rows,
            "harness" => harness_rows,
        };
        std::fs::write(path, doc.to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}

/// `ecmac bench --forward`: the tiled-kernel batched GEMM and the
/// prefix-cached sweep engine against the kept-verbatim PR-3 and PR-4
/// reference paths (`testkit`), per topology, plus per-kernel
/// micro-benches and the multi-core row-partitioned batch.  Writes the
/// `BENCH_forward.json` before/after artifact the CI bench-regression
/// gate compares against the committed baseline.
fn bench_forward(
    args: &ecmac::util::cli::Args,
    bench_cfg: ecmac::testkit::bench::BenchConfig,
    batch: usize,
) -> Result<()> {
    use ecmac::datapath::gemm;
    use ecmac::testkit::bench::Bencher;
    let specs: Vec<&str> = args
        .get("topologies")
        .unwrap_or("62,30,10;62,20,20,10")
        .split(';')
        .filter(|s| !s.is_empty())
        .collect();
    let sweep_images: usize = args.get_or("sweep-images", 64)?;
    anyhow::ensure!(sweep_images >= 1, "--sweep-images must be at least 1");
    let par_batch: usize = args.get_or("par-batch", 512)?;
    gemm::set_kernel_override(gemm::Kernel::parse(args.get("kernel").unwrap_or("auto"))?)?;
    println!(
        "gemm kernel: {} (detected: {}, {} pool workers)\n",
        gemm::active_kernel(),
        gemm::detected_kernel(),
        ecmac::util::threadpool::shared_pool().workers(),
    );
    let mut b = Bencher::new(bench_cfg);
    let sched = ConfigSchedule::uniform(Config::new(9).unwrap());
    let mut rows: Vec<ecmac::util::json::Json> = Vec::new();
    let mut table_rows: Vec<report::ForwardBenchRow> = Vec::new();
    for spec_s in &specs {
        let topo = Topology::parse(spec_s)?;
        // registers the timed suites and asserts bit-exactness first
        // (every path and both kernels): the comparison is meaningless
        // otherwise
        ecmac::testkit::bench_forward_suite(&mut b, &topo, batch, &sched);
        if par_batch > 0 {
            ecmac::testkit::bench_forward_par(&mut b, &topo, par_batch, &sched);
        }
        ecmac::testkit::bench_sweep_pair(&mut b, &topo, sweep_images);
        let thrpt = |name: &str| {
            b.result(name)
                .and_then(|r| r.throughput_per_sec())
                .unwrap_or(-1.0)
        };
        let mean_ms = |name: &str| b.result(name).map(|r| r.mean_ns / 1e6).unwrap_or(-1.0);
        let row = report::ForwardBenchRow {
            topology: topo.to_string(),
            batch: batch as u64,
            per_image_per_sec: thrpt(&format!("forward/per_image_{topo}")),
            batch_reference_per_sec: thrpt(&format!("forward/batch_reference_{topo}")),
            batch_signed_per_sec: thrpt(&format!("forward/batch_signed_{topo}")),
            batch_per_sec: thrpt(&format!("forward/batch_{topo}")),
            tile_scalar_per_sec: thrpt(&format!("forward/tile_scalar_{topo}")),
            tile_avx2_per_sec: thrpt(&format!("forward/tile_avx2_{topo}")),
            batch_par_per_sec: thrpt(&format!("forward/batch_par{par_batch}_{topo}")),
            par_batch: par_batch as u64,
            sweep_jobs: 32 * topo.n_layers() as u64,
            sweep_full_ms: mean_ms(&format!("sweep/full_pass_{topo}")),
            sweep_cached_ms: mean_ms(&format!("sweep/prefix_cached_{topo}")),
        };
        rows.push(ecmac::json_obj! {
            "topology" => row.topology.clone(),
            "per_image_per_sec" => row.per_image_per_sec,
            "batch_reference_per_sec" => row.batch_reference_per_sec,
            "batch_signed_per_sec" => row.batch_signed_per_sec,
            "batch_per_sec" => row.batch_per_sec,
            "tile_scalar_per_sec" => row.tile_scalar_per_sec,
            "tile_avx2_per_sec" => row.tile_avx2_per_sec,
            "batch_par_per_sec" => row.batch_par_per_sec,
            "par_batch" => row.par_batch as f64,
            "batch_speedup" => row.batch_per_sec / row.batch_reference_per_sec.max(1e-9),
            "kernel_speedup" => row.batch_per_sec / row.batch_signed_per_sec.max(1e-9),
            "sweep_jobs" => row.sweep_jobs as f64,
            "sweep_reference_ms" => row.sweep_full_ms,
            "sweep_cached_ms" => row.sweep_cached_ms,
            "sweep_speedup" => row.sweep_full_ms / row.sweep_cached_ms.max(1e-9),
            "bit_exact" => true,
        });
        table_rows.push(row);
    }
    let harness_rows: Vec<ecmac::util::json::Json> = b
        .results()
        .iter()
        .map(|r| {
            ecmac::json_obj! {
                "name" => r.name.clone(),
                "mean_ns" => r.mean_ns,
                "median_ns" => r.median_ns,
                "p95_ns" => r.p95_ns,
                "throughput_per_sec" => r.throughput_per_sec().unwrap_or(-1.0),
            }
        })
        .collect();
    b.finish();
    println!("\nforward hot path + sweep engine (before -> after):");
    println!("{}", report::forward_bench_table(&table_rows));
    if let Some(path) = args.get("json") {
        let doc = ecmac::json_obj! {
            "schema_version" => 2usize,
            "bench" => "forward",
            "batch" => batch,
            "sweep_images" => sweep_images,
            "kernel" => gemm::active_kernel().to_string(),
            "detected_kernel" => gemm::detected_kernel().to_string(),
            "rows" => rows,
            "harness" => harness_rows,
        };
        std::fs::write(path, doc.to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}

/// `ecmac bench --pipeline`: the stage-pipelined batch executor
/// against the row-partitioned `forward_batch` on the same inputs, per
/// topology, after asserting bit-exactness.  Deep synthetic topologies
/// need no artifacts (`Topology::parse` accepts `784x128x64x10`); the
/// default set includes the shallow seed shape so the artifact also
/// records an honest planner-fallback row.  Uses `--par-batch` as the
/// batch size (the pipeline only engages at row-partition scale) and a
/// first-layer-approximate per-layer schedule so stage boundaries have
/// a table-residency trade-off to respect.  Writes a
/// `BENCH_pipeline.json` artifact in the `forward` family; CI gates it
/// on in-run invariants only (`bench_gate.py` without `--baseline`).
fn bench_pipeline(
    args: &ecmac::util::cli::Args,
    bench_cfg: ecmac::testkit::bench::BenchConfig,
) -> Result<()> {
    use ecmac::testkit::bench::Bencher;
    let specs: Vec<&str> = args
        .get("topologies")
        .unwrap_or("784x128x64x10;62,30,10")
        .split(';')
        .filter(|s| !s.is_empty())
        .collect();
    let batch: usize = args.get_or("par-batch", 512)?;
    anyhow::ensure!(batch >= 1, "--par-batch must be at least 1");
    let pool_workers = ecmac::util::threadpool::shared_pool().workers();
    println!("stage pipeline vs row partition ({pool_workers} pool workers)\n");
    let mut b = Bencher::new(bench_cfg);
    let mut rows: Vec<ecmac::util::json::Json> = Vec::new();
    let mut table_rows: Vec<report::PipelineBenchRow> = Vec::new();
    for spec_s in &specs {
        let topo = Topology::parse(spec_s)?;
        // first layer approximate, rest accurate: a schedule boundary
        // the planner's table-residency penalty can align stages with
        let cfgs: Vec<Config> = (0..topo.n_layers())
            .map(|l| if l == 0 { Config::new(9).unwrap() } else { Config::ACCURATE })
            .collect();
        let sched = ConfigSchedule::per_layer(cfgs);
        // registers the timed pair and asserts bit-exactness first: the
        // comparison is meaningless otherwise
        let plan = ecmac::testkit::bench_pipeline_pair(&mut b, &topo, batch, &sched);
        let thrpt = |name: &str| {
            b.result(name)
                .and_then(|r| r.throughput_per_sec())
                .unwrap_or(-1.0)
        };
        let par = thrpt(&format!("forward/batch_par{batch}_{topo}"));
        let piped = thrpt(&format!("pipeline/batch{batch}_{topo}"));
        let fallback = plan.is_none();
        let row = report::PipelineBenchRow {
            topology: topo.to_string(),
            batch: batch as u64,
            batch_par_per_sec: par,
            pipeline_per_sec: piped,
            plan: plan
                .as_ref()
                .map(|p| p.describe())
                .unwrap_or_else(|| "row-partition fallback".to_string()),
            stages: plan.as_ref().map(|p| p.stages().len() as u64).unwrap_or(0),
            workers: plan.as_ref().map(|p| p.total_workers() as u64).unwrap_or(0),
            fallback,
        };
        rows.push(ecmac::json_obj! {
            "topology" => row.topology.clone(),
            "batch" => batch,
            "batch_par_per_sec" => row.batch_par_per_sec,
            "pipeline_per_sec" => row.pipeline_per_sec,
            "pipeline_speedup" => row.pipeline_per_sec / row.batch_par_per_sec.max(1e-9),
            "plan" => row.plan.clone(),
            "stages" => row.stages as f64,
            "workers" => row.workers as f64,
            "pipeline_fallback" => row.fallback,
            "bit_exact" => true,
        });
        table_rows.push(row);
    }
    let harness_rows: Vec<ecmac::util::json::Json> = b
        .results()
        .iter()
        .map(|r| {
            ecmac::json_obj! {
                "name" => r.name.clone(),
                "mean_ns" => r.mean_ns,
                "median_ns" => r.median_ns,
                "p95_ns" => r.p95_ns,
                "throughput_per_sec" => r.throughput_per_sec().unwrap_or(-1.0),
            }
        })
        .collect();
    b.finish();
    println!("\nstage-pipelined vs row-partitioned batch (same inputs, bit-exact):");
    println!("{}", report::pipeline_bench_table(&table_rows));
    if let Some(path) = args.get("json") {
        let doc = ecmac::json_obj! {
            "schema_version" => 2usize,
            "bench" => "forward",
            "mode" => "pipeline",
            "batch" => batch,
            "pool_workers" => pool_workers as f64,
            "rows" => rows,
            "harness" => harness_rows,
        };
        std::fs::write(path, doc.to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}

/// `ecmac analyze`: the static-verification pass (DESIGN.md §Static
/// analysis).  For every (topology, schedule) pair it proves the
/// datapath value-range bounds from the measured per-configuration
/// product envelopes (`analysis::range`) and the liveness of every
/// plan the pipeline planner can emit (`analysis::liveness`, which
/// model-checks each plan's stage/queue protocol exhaustively).  Any
/// refuted or unknown check fails the command — the CI gate condition.
/// `--seed-violations` instead runs the deliberately-unsafe cases and
/// requires the analyzer to reject them with named-bound diagnostics.
fn cmd_analyze(argv: &[String]) -> Result<()> {
    use ecmac::analysis::{self, liveness, range, Summary};
    let spec = vec![
        OptSpec {
            name: "topologies",
            help: "';'-separated topology specs to verify",
            takes_value: true,
            default: Some("62,30,10;784x128x64x10"),
        },
        OptSpec {
            name: "schedule",
            help: "'all' = all 33 uniform configs + a mixed per-layer schedule, \
                   or one schedule (e.g. '9' or '9,0,0')",
            takes_value: true,
            default: Some("all"),
        },
        OptSpec {
            name: "workers",
            help: "pool-worker ceiling for the planner-space sweep",
            takes_value: true,
            default: Some("8"),
        },
        OptSpec {
            name: "batch",
            help: "batch size the planner decisions are checked at",
            takes_value: true,
            default: Some("512"),
        },
        OptSpec {
            name: "json",
            help: "write the ANALYZE.json artifact here",
            takes_value: true,
            default: None,
        },
        OptSpec {
            name: "seed-violations",
            help: "run the deliberately-unsafe cases and require refutation",
            takes_value: false,
            default: None,
        },
    ];
    let args = Args::parse(argv, &spec)?;
    if args.flag("seed-violations") {
        return analyze_seed_violations();
    }
    let specs: Vec<&str> = args
        .get("topologies")
        .unwrap_or("62,30,10;784x128x64x10")
        .split(';')
        .filter(|s| !s.is_empty())
        .collect();
    let max_workers: usize = args.get_or("workers", 8)?;
    let batch: usize = args.get_or("batch", 512)?;
    let sched_arg = args.get("schedule").unwrap_or("all");

    let mut rows_json: Vec<ecmac::util::json::Json> = Vec::new();
    let mut table_rows: Vec<report::AnalyzeRow> = Vec::new();
    let mut grand = Summary::default();
    let mut failures: Vec<(String, analysis::Check)> = Vec::new();
    for spec_s in &specs {
        let topo = Topology::parse(spec_s)?;
        // weights only feed the weight-aware diagnostics and the cost
        // model's MAC counts; every *verdict* is weight-agnostic
        let net = Network::new(QuantWeights::random(&topo, 0xECAC));
        let scheds: Vec<(String, ConfigSchedule)> = if sched_arg == "all" {
            let mut s: Vec<(String, ConfigSchedule)> = Config::all()
                .map(|c| (format!("cfg{}", c.index()), ConfigSchedule::uniform(c)))
                .collect();
            // a mixed schedule so stage boundaries carry a
            // table-residency trade-off, like the pipeline bench
            let cfgs: Vec<Config> = (0..topo.n_layers())
                .map(|l| if l == 0 { Config::new(9).unwrap() } else { Config::ACCURATE })
                .collect();
            s.push(("mixed".to_string(), ConfigSchedule::per_layer(cfgs)));
            s
        } else {
            let sched = ConfigSchedule::parse(sched_arg)?;
            sched.validate(topo.n_layers())?;
            vec![(sched_arg.to_string(), sched)]
        };
        for (label, sched) in scheds {
            let rr = range::verify_network(&net, &sched);
            let plans = liveness::verify_planner_space(&net, &sched, max_workers, &[batch]);
            let range_sum = rr.summary();
            let mut live_sum = Summary::default();
            for p in &plans {
                live_sum.merge(p.summary());
            }
            let mut combined = range_sum;
            combined.merge(live_sum);
            grand.merge(combined);
            let id = format!("{topo}@{label}");
            for c in analysis::failures(&rr.checks) {
                failures.push((id.clone(), c.clone()));
            }
            for p in &plans {
                for c in analysis::failures(&p.checks) {
                    failures.push((format!("{id} w{} b{}", p.workers, p.batch), c.clone()));
                }
            }
            table_rows.push(report::AnalyzeRow {
                id: id.clone(),
                topology: topo.to_string(),
                schedule: sched.to_string(),
                range: (range_sum.proved, range_sum.refuted, range_sum.unknown),
                liveness: (live_sum.proved, live_sum.refuted, live_sum.unknown),
                plans: (
                    plans.iter().filter(|p| p.plan.is_some()).count(),
                    plans.iter().filter(|p| p.plan.is_none()).count(),
                ),
                acc_bits: rr.layers.iter().map(|l| l.acc_bits).max().unwrap_or(0),
                headroom: rr
                    .layers
                    .iter()
                    .map(|l| l.headroom)
                    .fold(f64::INFINITY, f64::min),
            });
            rows_json.push(ecmac::json_obj! {
                "id" => id,
                "topology" => topo.to_string(),
                "schedule" => sched.to_string(),
                "checks" => rr.checks.iter().map(analysis::Check::to_json).collect::<Vec<_>>(),
                "layers" => rr.layers.iter().map(range::LayerRange::to_json).collect::<Vec<_>>(),
                "plans" => plans.iter().map(liveness::PlanReport::to_json).collect::<Vec<_>>(),
                "summary" => combined.to_json(),
            });
        }
    }

    println!(
        "static verification: {} topologies x {} schedule(s), planner space \
         workers 1..={max_workers} @ batch {batch}\n",
        specs.len(),
        if sched_arg == "all" { "34".to_string() } else { "1".to_string() },
    );
    println!("{}", report::analyze_table(&table_rows));
    println!(
        "checks: {} proved, {} refuted, {} unknown",
        grand.proved, grand.refuted, grand.unknown
    );
    if let Some(path) = args.get("json") {
        let doc = ecmac::json_obj! {
            "schema_version" => 1usize,
            "bench" => "analyze",
            "max_workers" => max_workers,
            "batch" => batch,
            "rows" => rows_json,
            "summary" => grand.to_json(),
        };
        std::fs::write(path, doc.to_string())?;
        println!("wrote {path}");
    }
    if !failures.is_empty() {
        eprintln!();
        for (id, c) in &failures {
            eprintln!("[{id}] {} {}: {}", c.verdict, c.name, c.detail);
        }
        anyhow::bail!(
            "analyze: {} refuted and {} unknown check(s) — see diagnostics above",
            grand.refuted,
            grand.unknown
        );
    }
    Ok(())
}

/// `ecmac analyze --seed-violations`: drive the analyzer with inputs
/// constructed to be unsafe and require refutation with a diagnostic
/// naming the violated bound — the negative half of the CI gate.
fn analyze_seed_violations() -> Result<()> {
    use ecmac::analysis::{liveness, range, Verdict};
    use ecmac::datapath::pipeline::Plan;
    let tables = ecmac::amul::MulTables::build();
    let sched = ConfigSchedule::uniform(Config::ACCURATE);

    // 1. a fan-in one past the analyzer's own cap (Topology::new
    //    refuses to construct this — verify_raw_sizes takes raw sizes)
    let sizes = [range::MAX_FAN_IN_ANY_CONFIG + 1, 32, 10];
    let rr = range::verify_raw_sizes(&sizes, &sched, &tables);
    let f = rr
        .checks
        .iter()
        .find(|c| c.verdict == Verdict::Refuted)
        .ok_or_else(|| anyhow::anyhow!("oversized fan-in was not refuted"))?;
    anyhow::ensure!(
        f.name == "layer0.i32-acc" && f.detail.contains("violated bound"),
        "refutation must name the violated bound per layer, got {}: {}",
        f.name,
        f.detail
    );
    println!("seeded violation 1 (oversized fan-in) refuted as expected:");
    println!("  [{}] {}\n", f.name, f.detail);

    // 2. a forced pipeline plan wider than the pool it would run on
    let topo = Topology::parse("784x128x64x10")?;
    let net = Network::new(QuantWeights::random(&topo, 0xECAC));
    let plan = Plan::forced(&net, &sched, 3, 32);
    let checks = liveness::verify_plan(&net, &plan, 2);
    let f = checks
        .iter()
        .find(|c| c.verdict == Verdict::Refuted)
        .ok_or_else(|| anyhow::anyhow!("oversubscribed plan was not refuted"))?;
    anyhow::ensure!(
        f.name.ends_with(".residency") && f.detail.contains("violated bound"),
        "refutation must name the violated bound per stage, got {}: {}",
        f.name,
        f.detail
    );
    println!("seeded violation 2 (oversubscribed plan) refuted as expected:");
    println!("  [{}] {}", f.name, f.detail);
    println!("\nboth seeded violations rejected with named-bound diagnostics");
    Ok(())
}

fn parse_policy(s: &str) -> Result<Policy> {
    let parts: Vec<&str> = s.split(':').collect();
    match parts.as_slice() {
        ["fixed", cfg] => Ok(Policy::Fixed(
            Config::new(cfg.parse()?).context("cfg out of range")?,
        )),
        ["sched", list] => Ok(Policy::FixedSchedule(ConfigSchedule::parse(list)?)),
        ["budget", mw] => Ok(Policy::PowerBudget {
            budget_mw: mw.parse()?,
        }),
        ["floor", acc] => Ok(Policy::AccuracyFloor {
            min_accuracy: acc.parse()?,
        }),
        ["energy", mj, imgs] => Ok(Policy::EnergyBudget {
            budget_mj: mj.parse()?,
            horizon_images: imgs.parse()?,
        }),
        _ => anyhow::bail!(
            "bad policy '{s}' (fixed:<cfg> | sched:<cfg,..> | budget:<mw> | floor:<acc> | \
             energy:<mj>:<images>)"
        ),
    }
}

/// Scripted fault-injection campaign: inject one fault class at a time
/// — table SRAM stuck-at/flip, accumulator SEU, pipeline stage
/// stall/panic, flaky + stalling backends, a dropped intake connection
/// — and verify each ends masked, detected+degraded, or failed-fast;
/// never silent, never hung.  `--json CHAOS.json` feeds the CI gate.
fn cmd_chaos(argv: &[String]) -> Result<()> {
    let spec = vec![
        OptSpec {
            name: "seed",
            help: "fault-coordinate / input seed (the campaign is \
                   reproducible from it alone)",
            takes_value: true,
            default: Some("20260807"),
        },
        OptSpec {
            name: "json",
            help: "write the CHAOS.json artifact here",
            takes_value: true,
            default: None,
        },
    ];
    let args = Args::parse(argv, &spec)?;
    let seed: u64 = args.get_or("seed", 20260807)?;

    println!("chaos campaign (seed {seed}): injecting one fault class at a time\n");
    let report = ecmac::chaos::run_campaign(seed);
    println!("{:<20} {:<19} detail", "class", "outcome");
    for c in &report.classes {
        println!("{:<20} {:<19} {}", c.class, c.outcome.as_str(), c.detail);
    }
    let contained = report.all_contained();
    println!(
        "\n{} classes, all contained: {contained}",
        report.classes.len()
    );
    if let Some(path) = args.get("json") {
        std::fs::write(path, report.to_json().to_string())?;
        println!("wrote {path}");
    }
    anyhow::ensure!(
        contained,
        "campaign left a fault class silent or hung (see table above)"
    );
    Ok(())
}

fn cmd_sentinel(argv: &[String]) -> Result<()> {
    let spec = vec![
        OptSpec {
            name: "seed",
            help: "input / anomaly-coordinate seed (the campaign is \
                   reproducible from it alone)",
            takes_value: true,
            default: Some("20260807"),
        },
        OptSpec {
            name: "json",
            help: "write the SENTINEL.json artifact here",
            takes_value: true,
            default: None,
        },
    ];
    let args = Args::parse(argv, &spec)?;
    let seed: u64 = args.get_or("seed", 20260807)?;

    println!("sentinel audit campaign (seed {seed}): one quiet anomaly class at a time\n");
    let report = ecmac::sentinel::campaign::run_campaign(seed);
    println!("{:<18} {:<20} detail", "class", "outcome");
    for c in &report.classes {
        println!("{:<18} {:<20} {}", c.class, c.outcome.as_str(), c.detail);
    }
    let resolved = report.all_resolved();
    println!(
        "\n{} classes, all detected-and-recovered or clean: {resolved}",
        report.classes.len()
    );
    if let Some(path) = args.get("json") {
        std::fs::write(path, report.to_json().to_string())?;
        println!("wrote {path}");
    }
    anyhow::ensure!(
        resolved,
        "audit campaign left a class silent, unrecovered or hung (see table above)"
    );
    Ok(())
}

fn cmd_ablation(argv: &[String]) -> Result<()> {
    let mut spec = common_opts();
    spec.push(OptSpec {
        name: "limit",
        help: "test images to evaluate (0 = all)",
        takes_value: true,
        default: Some("4000"),
    });
    let args = Args::parse(argv, &spec)?;
    let dir = artifacts_dir(&args);
    let ds = Dataset::load_test(&dir)?;
    let limit: usize = args.get_or("limit", 4000)?;
    let n = if limit == 0 { ds.len() } else { limit.min(ds.len()) };
    let net = Network::new(QuantWeights::load_artifacts(&dir)?);
    let pm = power_model(&dir, 32)?;

    // named heterogeneous assignments over the 10 physical neurons
    let worst = Config::MAX_APPROX;
    let acc0 = Config::ACCURATE;
    let mut half = [acc0; 10];
    for (p, c) in half.iter_mut().enumerate() {
        if p % 2 == 1 {
            *c = worst;
        }
    }
    let mut three_quarters = [worst; 10];
    for c in three_quarters.iter_mut().take(3) {
        *c = acc0;
    }
    let mid = Config::new(16).unwrap();
    let assignments: Vec<(&str, [Config; 10])> = vec![
        ("all-accurate", [acc0; 10]),
        ("all-mid(16)", [mid; 10]),
        ("all-worst(32)", [worst; 10]),
        ("alternating acc/worst", half),
        ("3 accurate + 7 worst", three_quarters),
    ];

    println!(
        "heterogeneous per-neuron configuration ablation ({n} test images)\n\
         (extends the paper: per-MAC config is a finer knob than the global one)\n"
    );
    let mut t = ecmac::report::TextTable::new(&[
        "assignment",
        "accuracy %",
        "power mW",
        "saving %",
    ]);
    let p0 = pm.breakdown(Config::ACCURATE).total_mw;
    for (name, cfgs) in &assignments {
        let acc = net.accuracy_hetero(&ds.features[..n], &ds.labels[..n], cfgs);
        let p = pm.total_hetero_mw(cfgs);
        t.row(vec![
            name.to_string(),
            format!("{:.2}", acc * 100.0),
            format!("{:.3}", p),
            format!("{:.2}", (p0 - p) / p0 * 100.0),
        ]);
    }
    println!("{}", t.render());
    println!(
        "note: intermediate assignments open operating points between the\n\
         paper's global configurations — e.g. output-critical neurons can\n\
         stay accurate while the rest save power."
    );
    Ok(())
}

fn cmd_verilog(argv: &[String]) -> Result<()> {
    let mut spec = common_opts();
    spec.push(OptSpec {
        name: "out",
        help: "output file for the module (default: stdout)",
        takes_value: true,
        default: None,
    });
    spec.push(OptSpec {
        name: "testbench",
        help: "also write a self-checking testbench for this config",
        takes_value: true,
        default: None,
    });
    let args = Args::parse(argv, &spec)?;
    let m = ecmac::netlist::multiplier::MultiplierNet::build();
    let v = ecmac::netlist::verilog::multiplier_verilog(&m);
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, &v)?;
            println!("wrote {path} ({} lines)", v.lines().count());
        }
        None => print!("{v}"),
    }
    if let Some(cfg_s) = args.get("testbench") {
        let cfg = Config::new(cfg_s.parse()?).context("cfg must be 0..=32")?;
        let mut rng = ecmac::util::rng::Pcg32::new(2024);
        let vectors: Vec<(u32, u32)> =
            (0..64).map(|_| (rng.below(128), rng.below(128))).collect();
        let tb = ecmac::netlist::verilog::multiplier_testbench(cfg, &vectors);
        let path = format!("tb_approx_mul_cfg{}.v", cfg.index());
        std::fs::write(&path, tb)?;
        println!("wrote {path}");
    }
    Ok(())
}

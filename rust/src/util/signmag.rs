//! Sign-magnitude (MSB = sign, low 7 bits = magnitude) encoding helpers.
//!
//! This is the *single* home of the encoding logic: the multiplier model
//! (`amul`), the datapath, the weights loader and the report emitters all
//! decode the same 8-bit format, and before this module each grew its own
//! copy of the bit-twiddling.  `amul::sm` re-exports this module so the
//! historical `sm::decode` call sites keep working.

/// Maximum magnitude representable (7 bits).
pub const MAG_MAX: u32 = 127;

/// Encode a signed integer in [-127, 127].
#[inline]
pub fn encode(v: i32) -> u8 {
    debug_assert!(v.unsigned_abs() <= MAG_MAX);
    if v < 0 {
        (0x80 | (-v)) as u8
    } else {
        v as u8
    }
}

/// Decode an 8-bit sign-magnitude value (0x80, "negative zero", decodes
/// to 0).
#[inline]
pub fn decode(enc: u8) -> i32 {
    let mag = (enc & 0x7F) as i32;
    if enc & 0x80 != 0 {
        -mag
    } else {
        mag
    }
}

/// Sign bit (0 or 1).
#[inline]
pub fn sign(enc: u8) -> u32 {
    (enc >> 7) as u32
}

/// Magnitude bits.
#[inline]
pub fn mag(enc: u8) -> u32 {
    (enc & 0x7F) as u32
}

/// Apply the product sign to an unsigned magnitude: the result is
/// negative exactly when the operand signs differ and the magnitude is
/// non-zero (the MAC's XOR sign logic; zero never becomes -0).
///
/// Branchless: `neg` is 0 or -1, `(mag ^ neg) - neg` negates exactly
/// when `neg == -1`.  This is the one implementation shared by the
/// bit-level model, the product tables and the table-row hot path.
#[inline(always)]
pub fn apply_sign(product_mag: i32, x: u8, w: u8) -> i32 {
    let neg = -((((x ^ w) >> 7) & 1) as i32);
    (product_mag ^ neg) - neg
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::prop::{check, gen_i64, gen_tuple2};

    #[test]
    fn roundtrip_exhaustive() {
        for v in -127..=127 {
            assert_eq!(decode(encode(v)), v);
        }
    }

    #[test]
    fn negative_zero_decodes_to_zero() {
        assert_eq!(decode(0x80), 0);
        // and the canonical encoding of 0 is +0
        assert_eq!(encode(0), 0);
    }

    #[test]
    fn sign_and_mag_split_the_byte() {
        for enc in 0..=255u8 {
            assert_eq!((sign(enc) << 7) | mag(enc), enc as u32);
            assert_eq!(decode(enc), if sign(enc) == 1 { -(mag(enc) as i32) } else { mag(enc) as i32 });
        }
    }

    #[test]
    fn apply_sign_matches_branchy_reference_exhaustively() {
        // exhaustive over both sign bits and a magnitude sweep
        for x in [0u8, 1, 0x7F, 0x80, 0x81, 0xFF] {
            for w in [0u8, 1, 0x7F, 0x80, 0x81, 0xFF] {
                for m in [0i32, 1, 500, 16129] {
                    let want = if (sign(x) ^ sign(w)) != 0 && m != 0 { -m } else { m };
                    assert_eq!(apply_sign(m, x, w), want, "x={x:#x} w={w:#x} m={m}");
                }
            }
        }
    }

    #[test]
    fn prop_encode_decode_roundtrip() {
        check("signmag roundtrip", 500, gen_i64(-127, 127), |&v| {
            decode(encode(v as i32)) == v as i32
        });
    }

    #[test]
    fn prop_apply_sign_is_sign_xor() {
        check(
            "apply_sign = XOR of operand signs",
            2000,
            gen_tuple2(
                gen_tuple2(gen_i64(-127, 127), gen_i64(-127, 127)),
                gen_i64(0, 16129),
            ),
            |&((x, w), m)| {
                let xe = encode(x as i32);
                let we = encode(w as i32);
                let p = apply_sign(m as i32, xe, we);
                if m == 0 {
                    p == 0
                } else {
                    (p < 0) == ((x < 0) != (w < 0))
                }
            },
        );
    }
}

//! Streaming statistics helpers used by the power model, the benchmark
//! harness and the coordinator's latency metrics.

/// Welford online mean/variance accumulator.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        self.m2 += other.m2 + d * d * self.n as f64 * other.n as f64 / n as f64;
        self.mean = mean;
        self.n = n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Percentile over a sample set (exact, nearest-rank with interpolation).
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of empty slice");
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Latency histogram with power-of-two microsecond buckets; cheap to
/// update from the coordinator's hot path.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    buckets: Vec<u64>, // bucket i counts values in [2^i, 2^(i+1)) us
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self {
            buckets: vec![0; 40],
            count: 0,
            sum_us: 0,
            max_us: 0,
        }
    }

    pub fn record_us(&mut self, us: u64) {
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(self.buckets.len() - 1);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    /// Approximate percentile from the histogram (upper bucket bound).
    pub fn percentile_us(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (p / 100.0 * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        self.max_us
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        let naive_var =
            xs.iter().map(|x| (x - 5.0) * (x - 5.0)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((w.variance() - naive_var).abs() < 1e-12);
        assert_eq!(w.min(), 2.0);
        assert_eq!(w.max(), 9.0);
    }

    #[test]
    fn welford_merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut whole = Welford::new();
        for &x in &xs {
            whole.push(x);
        }
        let mut a = Welford::new();
        let mut b = Welford::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - whole.mean()).abs() < 1e-9);
        assert!((a.variance() - whole.variance()).abs() < 1e-9);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_percentiles_monotone() {
        let mut h = LatencyHistogram::new();
        for us in [1u64, 3, 10, 100, 1000, 10_000, 100_000] {
            for _ in 0..10 {
                h.record_us(us);
            }
        }
        assert!(h.percentile_us(50.0) <= h.percentile_us(90.0));
        assert!(h.percentile_us(90.0) <= h.percentile_us(99.9));
        assert_eq!(h.count(), 70);
        assert_eq!(h.max_us(), 100_000);
    }

    #[test]
    fn histogram_merge() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record_us(10);
        b.record_us(1000);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.max_us(), 1000);
    }
}

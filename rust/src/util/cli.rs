//! Tiny CLI argument parser (no `clap` offline).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`, and
//! positional arguments, with typed accessors and a generated usage
//! string.  Strict: unknown options are errors, so typos fail fast.

use std::collections::BTreeMap;

/// Declarative option spec used for usage output and validation.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

/// Parsed arguments for one (sub)command.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

#[derive(Debug, thiserror::Error)]
pub enum CliError {
    #[error("unknown option --{0}")]
    UnknownOption(String),
    #[error("option --{0} requires a value")]
    MissingValue(String),
    #[error("invalid value for --{name}: {value} ({why})")]
    BadValue {
        name: String,
        value: String,
        why: String,
    },
}

impl Args {
    /// Parse `argv` (without the program/subcommand name) against a spec.
    pub fn parse(argv: &[String], spec: &[OptSpec]) -> Result<Args, CliError> {
        let mut out = Args::default();
        for s in spec {
            if let Some(d) = s.default {
                out.values.insert(s.name.to_string(), d.to_string());
            }
        }
        let mut it = argv.iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let Some(s) = spec.iter().find(|s| s.name == name) else {
                    return Err(CliError::UnknownOption(name));
                };
                if s.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .cloned()
                            .ok_or_else(|| CliError::MissingValue(name.clone()))?,
                    };
                    out.values.insert(name, val);
                } else {
                    if inline_val.is_some() {
                        return Err(CliError::BadValue {
                            name,
                            value: inline_val.unwrap(),
                            why: "flag takes no value".into(),
                        });
                    }
                    out.flags.push(name);
                }
            } else {
                out.positional.push(arg.clone());
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, CliError>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v.parse::<T>().map(Some).map_err(|e| CliError::BadValue {
                name: name.to_string(),
                value: v.to_string(),
                why: e.to_string(),
            }),
        }
    }

    /// Typed getter with a non-spec default.
    pub fn get_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, CliError>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.get_parsed(name)?.unwrap_or(default))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// Render a usage block for a subcommand.
pub fn usage(cmd: &str, about: &str, spec: &[OptSpec]) -> String {
    let mut s = format!("{cmd} — {about}\n\noptions:\n");
    for o in spec {
        let val = if o.takes_value { " <value>" } else { "" };
        let def = o
            .default
            .map(|d| format!(" [default: {d}]"))
            .unwrap_or_default();
        s.push_str(&format!("  --{}{val}\n      {}{def}\n", o.name, o.help));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Vec<OptSpec> {
        vec![
            OptSpec {
                name: "count",
                help: "how many",
                takes_value: true,
                default: Some("10"),
            },
            OptSpec {
                name: "verbose",
                help: "chatty",
                takes_value: false,
                default: None,
            },
            OptSpec {
                name: "path",
                help: "a path",
                takes_value: true,
                default: None,
            },
        ]
    }

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&sv(&[]), &spec()).unwrap();
        assert_eq!(a.get_or::<u32>("count", 0).unwrap(), 10);
        assert!(!a.flag("verbose"));
        assert_eq!(a.get("path"), None);
    }

    #[test]
    fn parses_values_and_flags() {
        let a = Args::parse(&sv(&["--count", "5", "--verbose", "pos1"]), &spec()).unwrap();
        assert_eq!(a.get_or::<u32>("count", 0).unwrap(), 5);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn equals_form() {
        let a = Args::parse(&sv(&["--count=7"]), &spec()).unwrap();
        assert_eq!(a.get_or::<u32>("count", 0).unwrap(), 7);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(matches!(
            Args::parse(&sv(&["--nope"]), &spec()),
            Err(CliError::UnknownOption(_))
        ));
    }

    #[test]
    fn missing_value_rejected() {
        assert!(matches!(
            Args::parse(&sv(&["--path"]), &spec()),
            Err(CliError::MissingValue(_))
        ));
    }

    #[test]
    fn bad_typed_value() {
        let a = Args::parse(&sv(&["--count", "abc"]), &spec()).unwrap();
        assert!(a.get_or::<u32>("count", 0).is_err());
    }

    #[test]
    fn usage_contains_options() {
        let u = usage("demo", "test command", &spec());
        assert!(u.contains("--count"));
        assert!(u.contains("[default: 10]"));
    }
}

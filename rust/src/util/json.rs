//! Minimal JSON parser/serializer.
//!
//! The offline crate set has no `serde`/`serde_json`, so the artifact
//! interchange files (`weights_q.json`, `golden_mul.json`, ...) are read
//! through this small, strict JSON implementation.  It supports the full
//! JSON grammar except for exotic number forms beyond f64 precision, and
//! keeps object key order (insertion order) for deterministic output.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {offset}: {msg}")]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl Json {
    /// Parse a JSON document from a string.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    /// Parse a JSON file.
    pub fn from_file(path: &std::path::Path) -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Ok(Json::parse(&text)?)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access: `j.get("key")`.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Required-field access with a contextual error.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json field '{key}'"))
    }

    /// Flatten a numeric array (arbitrary nesting) into f64s.
    pub fn flat_f64(&self) -> anyhow::Result<Vec<f64>> {
        let mut out = Vec::new();
        fn rec(j: &Json, out: &mut Vec<f64>) -> anyhow::Result<()> {
            match j {
                Json::Num(n) => out.push(*n),
                Json::Arr(a) => {
                    for v in a {
                        rec(v, out)?;
                    }
                }
                other => anyhow::bail!("expected number/array, got {other:?}"),
            }
            Ok(())
        }
        rec(self, &mut out)?;
        Ok(out)
    }

    /// Flatten a numeric array into i32s (checked).
    pub fn flat_i32(&self) -> anyhow::Result<Vec<i32>> {
        Ok(self
            .flat_f64()?
            .into_iter()
            .map(|f| f as i32)
            .collect())
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            offset: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex"))?;
                        }
                        // surrogate pairs
                        if (0xD800..0xDC00).contains(&code) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone surrogate"));
                            }
                            let mut lo = 0u32;
                            for _ in 0..4 {
                                let c =
                                    self.bump().ok_or_else(|| self.err("bad \\u"))?;
                                lo = lo * 16
                                    + (c as char)
                                        .to_digit(16)
                                        .ok_or_else(|| self.err("bad hex"))?;
                            }
                            code = 0x10000 + ((code - 0xD800) << 10) + (lo - 0xDC00);
                        }
                        out.push(
                            char::from_u32(code).ok_or_else(|| self.err("bad char"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            0xF0..=0xF7 => 4,
                            _ => return Err(self.err("bad utf8")),
                        };
                        let start = self.pos - 1;
                        for _ in 1..len {
                            self.bump().ok_or_else(|| self.err("bad utf8"))?;
                        }
                        let s = std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("bad utf8"))?;
                        out.push_str(s);
                    }
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

impl fmt::Display for Json {
    /// Compact serialization (round-trips through `parse`).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Convenience builders for emitting reports.
impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<i32> for Json {
    fn from(v: i32) -> Self {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Build a `Json::Obj` from key/value pairs.
#[macro_export]
macro_rules! json_obj {
    ($($key:expr => $val:expr),* $(,)?) => {{
        let mut m = std::collections::BTreeMap::new();
        $( m.insert($key.to_string(), $crate::util::json::Json::from($val)); )*
        $crate::util::json::Json::Obj(m)
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str(), Some("x"));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_escapes() {
        let j = Json::parse(r#""a\n\t\"\\ A 😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\n\t\"\\ A 😀");
    }

    #[test]
    fn parse_unicode_passthrough() {
        let j = Json::parse("\"héllo ✓\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo ✓");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,-3],"b":{"c":"d\ne"},"e":true,"f":null}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn flat_i32_nested() {
        let j = Json::parse("[[1,2],[3,4],[5,6]]").unwrap();
        assert_eq!(j.flat_i32().unwrap(), vec![1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn json_obj_macro() {
        let j = json_obj! {"x" => 1, "y" => "two", "z" => vec![1.0, 2.0]};
        assert_eq!(j.get("x").unwrap().as_i64(), Some(1));
        assert_eq!(j.get("y").unwrap().as_str(), Some("two"));
        assert_eq!(j.get("z").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn big_flat_parse() {
        let n = 10_000;
        let src = format!(
            "[{}]",
            (0..n).map(|i| i.to_string()).collect::<Vec<_>>().join(",")
        );
        let j = Json::parse(&src).unwrap();
        assert_eq!(j.as_arr().unwrap().len(), n);
    }
}

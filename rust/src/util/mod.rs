//! Shared substrate utilities built from scratch for the offline crate
//! set: JSON, PRNGs, CLI parsing, thread pool/channels, statistics, the
//! idx dataset container, and the sign-magnitude encoding helpers.

pub mod cli;
pub mod idx;
pub mod json;
pub mod rng;
pub mod signmag;
pub mod stats;
pub mod threadpool;

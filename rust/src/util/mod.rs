//! Shared substrate utilities built from scratch for the offline crate
//! set: JSON, PRNGs, CLI parsing, thread pool/channels, statistics and
//! the idx dataset container.

pub mod cli;
pub mod idx;
pub mod json;
pub mod rng;
pub mod stats;
pub mod threadpool;

//! Deterministic PRNGs (no `rand` crate offline): SplitMix64 and PCG32.
//!
//! SplitMix64 seeds PCG32; PCG32 is the workhorse for workload
//! generation, the property-testing framework, and the benchmark
//! harness.  Everything downstream of a seed is fully deterministic, so
//! experiments in DESIGN.md are exactly reproducible.

/// SplitMix64 — tiny, solid seeder (Steele et al., "Fast Splittable PRNGs").
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG32 (XSH-RR 64/32) — O'Neill's minimal PCG.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let initstate = sm.next_u64();
        let initseq = sm.next_u64();
        let mut rng = Self {
            state: 0,
            inc: (initseq << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(initstate);
        rng.next_u32();
        rng
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, bound) without modulo bias (Lemire).
    pub fn below(&mut self, bound: u32) -> u32 {
        assert!(bound > 0, "below(0)");
        loop {
            let x = self.next_u32() as u64;
            let m = x * bound as u64;
            let l = m as u32;
            if l >= bound || l >= (u32::MAX - bound + 1) % bound {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform integer in the inclusive range [lo, hi].
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        if span == 0 {
            // full u64 range
            return self.next_u64() as i64;
        }
        let v = if span <= u32::MAX as u64 {
            self.below(span as u32) as u64
        } else {
            self.next_u64() % span // span > 2^32: bias is negligible for our use
        };
        lo + v as i64
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with the given rate (for Poisson arrival processes).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        -self.f64().max(1e-300).ln() / rate
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u32) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg32::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn range_inclusive_endpoints() {
        let mut rng = Pcg32::new(9);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..2000 {
            let v = rng.range_i64(-3, 3);
            assert!((-3..=3).contains(&v));
            lo_seen |= v == -3;
            hi_seen |= v == 3;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn f64_unit_interval() {
        let mut rng = Pcg32::new(11);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = rng.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::new(13);
        let n = 20_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = rng.normal();
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::new(17);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Pcg32::new(19);
        let n = 20_000;
        let rate = 4.0;
        let mean: f64 = (0..n).map(|_| rng.exponential(rate)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.02, "mean {mean}");
    }
}

//! Loader for idx-format image/label files (the MNIST container format).
//!
//! The build-time python pipeline writes the synthetic dataset in this
//! format, so this loader also works unchanged with a real MNIST
//! download if one is available.

use byteorder::{BigEndian, ReadBytesExt};
use std::io::Read;
use std::path::Path;

/// A set of images: `n` flattened `rows x cols` u8 images.
#[derive(Debug, Clone)]
pub struct Images {
    pub n: usize,
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<u8>, // n * rows * cols
}

impl Images {
    pub fn image(&self, i: usize) -> &[u8] {
        let sz = self.rows * self.cols;
        &self.data[i * sz..(i + 1) * sz]
    }
}

#[derive(Debug, thiserror::Error)]
pub enum IdxError {
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    #[error("bad idx magic {0:#010x} (expected {1:#010x})")]
    BadMagic(u32, u32),
    #[error("truncated idx file: expected {expected} bytes, got {got}")]
    Truncated { expected: usize, got: usize },
}

const IMAGES_MAGIC: u32 = 0x0000_0803;
const LABELS_MAGIC: u32 = 0x0000_0801;

/// Read an idx3 image file.
pub fn read_images(path: &Path) -> Result<Images, IdxError> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let magic = f.read_u32::<BigEndian>()?;
    if magic != IMAGES_MAGIC {
        return Err(IdxError::BadMagic(magic, IMAGES_MAGIC));
    }
    let n = f.read_u32::<BigEndian>()? as usize;
    let rows = f.read_u32::<BigEndian>()? as usize;
    let cols = f.read_u32::<BigEndian>()? as usize;
    let mut data = Vec::with_capacity(n * rows * cols);
    f.read_to_end(&mut data)?;
    if data.len() < n * rows * cols {
        return Err(IdxError::Truncated {
            expected: n * rows * cols,
            got: data.len(),
        });
    }
    data.truncate(n * rows * cols);
    Ok(Images { n, rows, cols, data })
}

/// Read an idx1 label file.
pub fn read_labels(path: &Path) -> Result<Vec<u8>, IdxError> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let magic = f.read_u32::<BigEndian>()?;
    if magic != LABELS_MAGIC {
        return Err(IdxError::BadMagic(magic, LABELS_MAGIC));
    }
    let n = f.read_u32::<BigEndian>()? as usize;
    let mut data = Vec::with_capacity(n);
    f.read_to_end(&mut data)?;
    if data.len() < n {
        return Err(IdxError::Truncated {
            expected: n,
            got: data.len(),
        });
    }
    data.truncate(n);
    Ok(data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_images(path: &Path, n: u32, rows: u32, cols: u32, data: &[u8]) {
        let mut f = std::fs::File::create(path).unwrap();
        f.write_all(&IMAGES_MAGIC.to_be_bytes()).unwrap();
        f.write_all(&n.to_be_bytes()).unwrap();
        f.write_all(&rows.to_be_bytes()).unwrap();
        f.write_all(&cols.to_be_bytes()).unwrap();
        f.write_all(data).unwrap();
    }

    #[test]
    fn roundtrip_images() {
        let dir = std::env::temp_dir().join("ecmac_idx_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("imgs.idx3");
        let data: Vec<u8> = (0..2 * 3 * 4).map(|i| i as u8).collect();
        write_images(&p, 2, 3, 4, &data);
        let im = read_images(&p).unwrap();
        assert_eq!((im.n, im.rows, im.cols), (2, 3, 4));
        assert_eq!(im.image(1), &data[12..24]);
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("ecmac_idx_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.idx3");
        std::fs::write(&p, [0u8; 16]).unwrap();
        assert!(matches!(read_images(&p), Err(IdxError::BadMagic(..))));
    }

    #[test]
    fn rejects_truncated() {
        let dir = std::env::temp_dir().join("ecmac_idx_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("trunc.idx3");
        write_images(&p, 10, 28, 28, &[0u8; 100]); // claims 7840 bytes
        assert!(matches!(read_images(&p), Err(IdxError::Truncated { .. })));
    }

    #[test]
    fn labels_roundtrip() {
        let dir = std::env::temp_dir().join("ecmac_idx_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("labels.idx1");
        let mut f = std::fs::File::create(&p).unwrap();
        f.write_all(&LABELS_MAGIC.to_be_bytes()).unwrap();
        f.write_all(&5u32.to_be_bytes()).unwrap();
        f.write_all(&[1, 2, 3, 4, 5]).unwrap();
        drop(f);
        assert_eq!(read_labels(&p).unwrap(), vec![1, 2, 3, 4, 5]);
    }
}

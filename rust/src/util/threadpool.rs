//! Fixed-size thread pool with a simple MPMC channel (no tokio offline).
//!
//! The coordinator's event loop and the benchmark harness both run on
//! this pool.  It provides:
//!   * `ThreadPool::execute` — fire-and-forget jobs
//!   * `ThreadPool::scatter` / `ThreadPool::scatter_scoped` — run a job
//!     list to completion with results in job order; the scoped variant
//!     accepts borrowing jobs, which is what lets the sensitivity sweep
//!     and the batched forward pass fan work out over shared read-only
//!     state without `Arc` plumbing
//!   * `shared_pool` — the process-wide pool library-internal
//!     parallelism (sweep scatter, `forward_batch` row partitioning)
//!     runs on, created on first use
//!   * `scope_map` — parallel map over a slice with result collection
//!   * `Channel` — a small blocking MPMC queue with close semantics and
//!     bounded capacity (the coordinator's backpressure primitive)

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::thread::JoinHandle;

// Under `--cfg loom` the channel's synchronization primitives come from
// loom, whose model checker (`mod loom_tests`) then enumerates every
// interleaving of the close/wake protocol.  Everything else in this
// module (the pool itself, the OS threads) is out of the loom models'
// reach and simply compiles against the same API surface.
#[cfg(loom)]
use loom::sync::{Arc, Condvar, Mutex};
#[cfg(not(loom))]
use std::sync::{Arc, Condvar, Mutex};

/// Blocking MPMC channel with optional capacity bound.
pub struct Channel<T> {
    inner: Arc<ChannelInner<T>>,
}

struct ChannelInner<T> {
    queue: Mutex<ChannelState<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

struct ChannelState<T> {
    items: VecDeque<T>,
    closed: bool,
}

impl<T> Clone for Channel<T> {
    fn clone(&self) -> Self {
        Self {
            inner: Arc::clone(&self.inner),
        }
    }
}

#[derive(Debug, PartialEq, Eq)]
pub enum SendError<T> {
    Closed(T),
}

impl<T> Channel<T> {
    /// `capacity = 0` means unbounded.
    pub fn new(capacity: usize) -> Self {
        Self {
            inner: Arc::new(ChannelInner {
                queue: Mutex::new(ChannelState {
                    items: VecDeque::new(),
                    closed: false,
                }),
                not_empty: Condvar::new(),
                not_full: Condvar::new(),
                capacity,
            }),
        }
    }

    /// Blocking send; returns the value back if the channel is closed.
    pub fn send(&self, value: T) -> Result<(), SendError<T>> {
        let mut st = self.inner.queue.lock().unwrap();
        loop {
            if st.closed {
                return Err(SendError::Closed(value));
            }
            if self.inner.capacity == 0 || st.items.len() < self.inner.capacity {
                st.items.push_back(value);
                self.inner.not_empty.notify_one();
                return Ok(());
            }
            st = self.inner.not_full.wait(st).unwrap();
        }
    }

    /// Non-blocking send attempt; `Ok(false)` when full.
    pub fn try_send(&self, value: T) -> Result<bool, SendError<T>> {
        let mut st = self.inner.queue.lock().unwrap();
        if st.closed {
            return Err(SendError::Closed(value));
        }
        if self.inner.capacity != 0 && st.items.len() >= self.inner.capacity {
            return Ok(false);
        }
        st.items.push_back(value);
        self.inner.not_empty.notify_one();
        Ok(true)
    }

    /// Blocking receive; `None` when the channel is closed and drained.
    pub fn recv(&self) -> Option<T> {
        let mut st = self.inner.queue.lock().unwrap();
        loop {
            if let Some(v) = st.items.pop_front() {
                self.inner.not_full.notify_one();
                return Some(v);
            }
            if st.closed {
                return None;
            }
            st = self.inner.not_empty.wait(st).unwrap();
        }
    }

    /// Non-blocking receive; `Ok(None)` when empty, `Err(())` when the
    /// channel is closed *and* drained (mirrors [`Self::recv_timeout`]).
    /// This is the poll primitive the TCP intake loop uses to check
    /// reply channels without parking the readiness loop.
    pub fn try_recv(&self) -> Result<Option<T>, ()> {
        let mut st = self.inner.queue.lock().unwrap();
        if let Some(v) = st.items.pop_front() {
            self.inner.not_full.notify_one();
            return Ok(Some(v));
        }
        if st.closed {
            return Err(());
        }
        Ok(None)
    }

    /// Receive with a timeout; `Ok(None)` on timeout.
    #[cfg(not(loom))]
    pub fn recv_timeout(&self, dur: std::time::Duration) -> Result<Option<T>, ()> {
        let deadline = std::time::Instant::now() + dur;
        let mut st = self.inner.queue.lock().unwrap();
        loop {
            if let Some(v) = st.items.pop_front() {
                self.inner.not_full.notify_one();
                return Ok(Some(v));
            }
            if st.closed {
                return Err(());
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Ok(None);
            }
            let (guard, res) = self
                .inner
                .not_empty
                .wait_timeout(st, deadline - now)
                .unwrap();
            st = guard;
            if res.timed_out() && st.items.is_empty() {
                if st.closed {
                    return Err(());
                }
                return Ok(None);
            }
        }
    }

    /// Receive with a timeout — loom variant.  Loom does not model
    /// time (`Condvar::wait_timeout` does not exist there), so the
    /// timeout is modeled as never firing and the call degrades to
    /// [`Self::recv`]: `Some` -> `Ok(Some)`, closed-and-drained ->
    /// `Err(())`.  Sound for the properties the models check — a
    /// timeout only ever *adds* a wakeup.
    #[cfg(loom)]
    pub fn recv_timeout(&self, _dur: std::time::Duration) -> Result<Option<T>, ()> {
        match self.recv() {
            Some(v) => Ok(Some(v)),
            None => Err(()),
        }
    }

    /// Drain everything currently queued without blocking.
    pub fn drain(&self) -> Vec<T> {
        let mut st = self.inner.queue.lock().unwrap();
        let out = st.items.drain(..).collect();
        self.inner.not_full.notify_all();
        out
    }

    pub fn close(&self) {
        let mut st = self.inner.queue.lock().unwrap();
        st.closed = true;
        self.inner.not_empty.notify_all();
        self.inner.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.queue.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

type Job = Box<dyn FnOnce() + Send + 'static>;

thread_local! {
    /// Whether the current thread is a [`ThreadPool`] worker — the
    /// guard [`ThreadPool::scatter_scoped`] uses to run nested scatters
    /// inline instead of deadlocking the pool on itself.
    static IN_POOL_WORKER: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Fixed-size worker pool.
pub struct ThreadPool {
    jobs: Channel<Job>,
    workers: Vec<JoinHandle<()>>,
    in_flight: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(n_threads: usize) -> Self {
        let jobs: Channel<Job> = Channel::new(0);
        let in_flight = Arc::new(AtomicUsize::new(0));
        let workers = (0..n_threads.max(1))
            .map(|i| {
                let jobs = jobs.clone();
                let in_flight = Arc::clone(&in_flight);
                std::thread::Builder::new()
                    .name(format!("ecmac-worker-{i}"))
                    .spawn(move || {
                        IN_POOL_WORKER.with(|f| f.set(true));
                        while let Some(job) = jobs.recv() {
                            // contain job panics: a dead worker would
                            // silently shrink the pool and leak
                            // in_flight, hanging every later scatter
                            let _ = std::panic::catch_unwind(
                                std::panic::AssertUnwindSafe(job),
                            );
                            in_flight.fetch_sub(1, Ordering::Release);
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self {
            jobs,
            workers,
            in_flight,
        }
    }

    /// Number of logical CPUs (fallback 4).
    pub fn default_parallelism() -> usize {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
    }

    /// Worker threads in this pool.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Whether the calling thread is a worker of *any* [`ThreadPool`].
    /// Library code that fans out implicitly (`forward_batch` row
    /// partitioning) checks this first: work already running on a pool
    /// thread stays serial there instead of re-scattering.
    pub fn on_worker_thread() -> bool {
        IN_POOL_WORKER.with(|f| f.get())
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.execute_boxed(Box::new(f));
    }

    fn execute_boxed(&self, job: Job) {
        self.in_flight.fetch_add(1, Ordering::Acquire);
        self.jobs
            .send(job)
            .unwrap_or_else(|_| panic!("pool closed"));
    }

    /// Busy-wait (with yield) until all submitted jobs finished.
    pub fn wait_idle(&self) {
        while self.in_flight.load(Ordering::Acquire) != 0 {
            std::thread::yield_now();
        }
    }

    /// Run `jobs` on the pool and block until every one completed,
    /// returning results in job order.  This is the coordinator's
    /// sub-batch primitive: a worker scatters one logical batch's
    /// shards, the pool threads execute them cooperatively, and the
    /// caller folds the shard results back into a single batch.
    ///
    /// Unlike [`scope_map`] the jobs are owned closures, so shards can
    /// carry their own data across threads without borrowing from the
    /// caller's stack.
    pub fn scatter<R, F>(&self, jobs: Vec<F>) -> Vec<R>
    where
        R: Send + 'static,
        F: FnOnce() -> R + Send + 'static,
    {
        /// Closes the results channel if the job unwinds, so the
        /// collector sees the loss (recv -> None -> panic with a clear
        /// message) instead of blocking forever on a result that will
        /// never arrive.
        struct PanicGuard<T>(Option<Channel<T>>);
        impl<T> Drop for PanicGuard<T> {
            fn drop(&mut self) {
                if let Some(ch) = self.0.take() {
                    ch.close();
                }
            }
        }
        let n = jobs.len();
        let done: Channel<(usize, R)> = Channel::new(0);
        for (i, job) in jobs.into_iter().enumerate() {
            let done = done.clone();
            self.execute(move || {
                let mut guard = PanicGuard(Some(done));
                let r = job();
                let ch = guard.0.take().expect("guard holds the channel until the send");
                let _ = ch.send((i, r));
            });
        }
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, r) = done.recv().expect("scatter job panicked before reporting");
            out[i] = Some(r);
        }
        out.into_iter().map(|o| o.expect("scatter result missing")).collect()
    }

    /// [`ThreadPool::scatter`] for *borrowing* jobs: run `jobs` on the
    /// pool, block until every one finished, and return the results in
    /// job order.  Jobs may capture references to the caller's stack
    /// (the sweep's shared checkpoint, a batch's input slice), which is
    /// what lets library hot paths fan out without `Arc`-wrapping their
    /// inputs.
    ///
    /// Called from a pool worker thread, the jobs run inline on the
    /// caller instead: a worker blocking on sub-jobs that need worker
    /// slots would deadlock the pool against itself once every worker
    /// nests.
    ///
    /// # Panics
    ///
    /// Re-raises the first failed job's own panic payload — but only
    /// after *every* submitted job has finished, which is also what
    /// makes the borrow erasure below sound.
    pub fn scatter_scoped<'env, R, F>(&self, jobs: Vec<F>) -> Vec<R>
    where
        R: Send + 'env,
        F: FnOnce() -> R + Send + 'env,
    {
        let n = jobs.len();
        if n == 0 {
            return Vec::new();
        }
        if Self::on_worker_thread() {
            return jobs.into_iter().map(|j| j()).collect();
        }
        struct Latch {
            done: Mutex<usize>,
            cv: Condvar,
        }
        impl Latch {
            fn wait_for(&self, n: usize) {
                let mut d = self.done.lock().unwrap();
                while *d < n {
                    d = self.cv.wait(d).unwrap();
                }
            }
        }
        /// Counts a job as done when its closure is dropped — normal
        /// return *and* unwind (the worker loop catches job panics), so
        /// the submitter's wait below can never miss a job.
        struct DoneGuard(Arc<Latch>);
        impl Drop for DoneGuard {
            fn drop(&mut self) {
                *self.0.done.lock().unwrap() += 1;
                self.0.cv.notify_all();
            }
        }
        /// Blocks in drop until every *submitted* job finished: even if
        /// submission itself unwinds, no borrowed job can outlive this
        /// call's stack frame.
        struct WaitGuard<'a> {
            latch: &'a Latch,
            submitted: usize,
        }
        impl Drop for WaitGuard<'_> {
            fn drop(&mut self) {
                self.latch.wait_for(self.submitted);
            }
        }
        let latch = Arc::new(Latch {
            done: Mutex::new(0),
            cv: Condvar::new(),
        });
        // each slot holds the job's result or its panic payload, so a
        // failing job's original message survives the pool hop
        type Slot<R> = Mutex<Option<std::thread::Result<R>>>;
        let slots: Vec<Slot<R>> = (0..n).map(|_| Mutex::new(None)).collect();
        {
            let mut wait = WaitGuard {
                latch: &latch,
                submitted: 0,
            };
            for (job, slot) in jobs.into_iter().zip(&slots) {
                let done = DoneGuard(Arc::clone(&latch));
                let boxed: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    let _done = done;
                    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                    *slot.lock().unwrap() = Some(r);
                });
                // SAFETY: the closure borrows `slots` and `'env` data.
                // `WaitGuard` (and its drop at the end of this block)
                // blocks until every submitted closure has run and been
                // dropped — on the success path and on any unwind — so
                // no borrow escapes this call.
                let boxed: Job = unsafe { erase_job_lifetime(boxed) };
                self.execute_boxed(boxed);
                wait.submitted += 1;
            }
            // WaitGuard drops here: blocks until all jobs completed
        }
        slots
            .into_iter()
            .map(|m| {
                match m.into_inner().unwrap().expect("scatter_scoped job lost") {
                    Ok(r) => r,
                    // re-raise the job's own panic with its payload
                    Err(payload) => std::panic::resume_unwind(payload),
                }
            })
            .collect()
    }
}

/// Erase a job closure's borrow lifetime so it can ride the pool's
/// `'static` job channel.  Sound only under [`ThreadPool::scatter_scoped`]'s
/// wait-for-completion discipline; never call this elsewhere.
unsafe fn erase_job_lifetime(job: Box<dyn FnOnce() + Send + '_>) -> Job {
    std::mem::transmute(job)
}

/// The process-wide shared pool library-internal parallelism runs on:
/// the sensitivity sweep's suffix jobs and `forward_batch`'s row
/// partitioning both scatter here, so one set of worker threads (sized
/// to the logical CPU count) serves every caller instead of each call
/// site spawning its own.  Created on first use; lives for the process.
pub fn shared_pool() -> &'static ThreadPool {
    static POOL: std::sync::OnceLock<ThreadPool> = std::sync::OnceLock::new();
    POOL.get_or_init(|| ThreadPool::new(ThreadPool::default_parallelism()))
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.jobs.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Parallel map over indexed chunks: applies `f(index, &item)` on `pool`,
/// returning results in input order.
pub fn scope_map<T, R, F>(pool: &ThreadPool, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send + 'static,
    F: Fn(usize, &T) -> R + Sync,
{
    let results: Vec<Mutex<Option<R>>> = (0..items.len()).map(|_| Mutex::new(None)).collect();
    // SAFETY-free approach: use crossbeam-style scoped threads via std.
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        let n_workers = ThreadPool::default_parallelism().min(items.len().max(1));
        let next = &next;
        let f = &f;
        let results = &results;
        for _ in 0..n_workers {
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(i, &items[i]);
                *results[i].lock().unwrap() = Some(r);
            });
        }
    });
    let _ = pool; // pool retained in the signature for future work-stealing use
    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("missing result"))
        .collect()
}

/// Parallel map without an explicit pool (scoped threads).
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let results: Vec<Mutex<Option<R>>> = (0..items.len()).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        let n_workers = ThreadPool::default_parallelism().min(items.len().max(1));
        let next = &next;
        let f = &f;
        let results = &results;
        for _ in 0..n_workers {
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(i, &items[i]);
                *results[i].lock().unwrap() = Some(r);
            });
        }
    });
    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("missing result"))
        .collect()
}

/// Exhaustive-interleaving models of the [`Channel`] close/wake
/// protocol (run via `RUSTFLAGS="--cfg loom" cargo test --lib loom`
/// with the loom dependency added for the job — see `ci.yml`).  Each
/// model asserts a property the pipeline's shutdown cascade relies on,
/// for **every** schedule loom can produce — the mechanized version of
/// the timing-based runtime tests below
/// (`close_wakes_a_sender_blocked_on_a_full_channel` etc.).
#[cfg(loom)]
mod loom_tests {
    use super::*;

    #[test]
    fn loom_close_wakes_sender_blocked_on_full_channel() {
        loom::model(|| {
            let ch: Channel<u32> = Channel::new(1);
            ch.send(1).unwrap();
            let ch2 = ch.clone();
            let sender = loom::thread::spawn(move || ch2.send(2));
            let ch3 = ch.clone();
            let closer = loom::thread::spawn(move || ch3.close());
            // the queue is full and nothing receives: whether the send
            // blocks first or observes `closed` first, it must resolve
            // to `Closed` — no lost wakeup, no missed flag
            assert_eq!(sender.join().unwrap(), Err(SendError::Closed(2)));
            closer.join().unwrap();
            // the queued item still drains after close
            assert_eq!(ch.recv(), Some(1));
            assert_eq!(ch.recv(), None);
        });
    }

    #[test]
    fn loom_close_wakes_receiver_blocked_on_empty_channel() {
        loom::model(|| {
            let ch: Channel<u32> = Channel::new(0);
            let ch2 = ch.clone();
            let receiver = loom::thread::spawn(move || ch2.recv());
            let ch3 = ch.clone();
            let sender = loom::thread::spawn(move || ch3.send(7));
            ch.close();
            let got = receiver.join().unwrap();
            match sender.join().unwrap() {
                // delivered: the receiver drains it even across a close
                Ok(()) => assert_eq!(got, Some(7)),
                // the close won: the receiver must wake to None, not hang
                Err(SendError::Closed(7)) => assert_eq!(got, None),
                Err(SendError::Closed(v)) => panic!("send returned a different item: {v}"),
            }
        });
    }

    #[test]
    fn loom_concurrent_sends_are_never_lost() {
        loom::model(|| {
            let ch: Channel<u32> = Channel::new(2);
            let a = {
                let ch = ch.clone();
                loom::thread::spawn(move || ch.send(1))
            };
            let b = {
                let ch = ch.clone();
                loom::thread::spawn(move || ch.send(2))
            };
            a.join().unwrap().unwrap();
            b.join().unwrap().unwrap();
            ch.close();
            let (x, y) = (ch.recv(), ch.recv());
            assert_eq!(x.unwrap() + y.unwrap(), 3, "both items must drain");
            assert_eq!(ch.recv(), None);
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn scatter_returns_results_in_job_order() {
        let pool = ThreadPool::new(4);
        let jobs: Vec<_> = (0..32u64)
            .map(|i| {
                move || {
                    if i % 3 == 0 {
                        std::thread::yield_now();
                    }
                    i * i
                }
            })
            .collect();
        let out = pool.scatter(jobs);
        assert_eq!(out, (0..32u64).map(|i| i * i).collect::<Vec<_>>());
        // the pool stays usable afterwards
        assert_eq!(pool.scatter(vec![|| 7u64]), vec![7]);
        assert!(pool.scatter(Vec::<fn() -> u64>::new()).is_empty());
    }

    #[test]
    fn scatter_survives_panicking_job() {
        let pool = ThreadPool::new(2);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.scatter(vec![
                Box::new(|| 1u64) as Box<dyn FnOnce() -> u64 + Send>,
                Box::new(|| panic!("injected job panic")),
            ])
        }));
        assert!(r.is_err(), "lost job must surface as a panic, not a hang");
        // the pool threads survived: a fresh scatter still completes
        assert_eq!(pool.scatter(vec![|| 5u64]), vec![5]);
        pool.wait_idle();
    }

    #[test]
    fn scatter_scoped_borrows_caller_data_in_order() {
        let pool = ThreadPool::new(4);
        let data: Vec<u64> = (0..64).collect();
        let jobs: Vec<_> = data
            .chunks(7)
            .map(|c| move || c.iter().sum::<u64>())
            .collect();
        let out = pool.scatter_scoped(jobs);
        assert_eq!(out.iter().sum::<u64>(), data.iter().sum::<u64>());
        // chunk order preserved
        let want: Vec<u64> = data.chunks(7).map(|c| c.iter().sum()).collect();
        assert_eq!(out, want);
        assert!(pool.scatter_scoped(Vec::<fn() -> u64>::new()).is_empty());
    }

    #[test]
    fn scatter_scoped_panics_only_after_all_jobs_finished() {
        let pool = ThreadPool::new(2);
        let ran = AtomicU64::new(0);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.scatter_scoped(vec![
                Box::new(|| {
                    ran.fetch_add(1, Ordering::SeqCst);
                    1u64
                }) as Box<dyn FnOnce() -> u64 + Send + '_>,
                Box::new(|| panic!("injected scoped job panic")),
                Box::new(|| {
                    ran.fetch_add(1, Ordering::SeqCst);
                    3u64
                }),
            ])
        }));
        assert!(r.is_err(), "a lost job must surface as a panic");
        // the surviving jobs all completed before the panic propagated
        assert_eq!(ran.load(Ordering::SeqCst), 2);
        // the pool stays usable
        assert_eq!(pool.scatter_scoped(vec![|| 9u64]), vec![9]);
    }

    #[test]
    fn scatter_scoped_nested_on_worker_runs_inline() {
        let pool = Arc::new(ThreadPool::new(2));
        // saturate every worker with jobs that themselves scatter:
        // without the inline fallback this deadlocks
        let p2 = Arc::clone(&pool);
        let jobs: Vec<_> = (0..4u64)
            .map(|i| {
                let pool = Arc::clone(&p2);
                move || {
                    assert!(ThreadPool::on_worker_thread());
                    let sub: Vec<_> = (0..2u64).map(|k| move || i * 10 + k).collect();
                    let inner = pool.scatter_scoped(sub);
                    inner.iter().sum::<u64>()
                }
            })
            .collect();
        let out = pool.scatter_scoped(jobs);
        assert_eq!(out, vec![1, 21, 41, 61]);
        assert!(!ThreadPool::on_worker_thread());
    }

    #[test]
    fn shared_pool_is_one_pool() {
        let a = shared_pool() as *const ThreadPool;
        let b = shared_pool() as *const ThreadPool;
        assert_eq!(a, b);
        assert!(shared_pool().workers() >= 1);
        let jobs: Vec<_> = (2u32..4).map(|v| move || v).collect();
        assert_eq!(shared_pool().scatter_scoped(jobs), vec![2, 3]);
    }

    #[test]
    fn channel_fifo() {
        let ch = Channel::new(0);
        for i in 0..10 {
            ch.send(i).unwrap();
        }
        for i in 0..10 {
            assert_eq!(ch.recv(), Some(i));
        }
    }

    #[test]
    fn channel_close_drains_then_none() {
        let ch = Channel::new(0);
        ch.send(1).unwrap();
        ch.close();
        assert_eq!(ch.recv(), Some(1));
        assert_eq!(ch.recv(), None);
        assert!(ch.send(2).is_err());
    }

    #[test]
    fn bounded_channel_backpressure() {
        let ch = Channel::new(2);
        assert!(ch.try_send(1).unwrap());
        assert!(ch.try_send(2).unwrap());
        assert!(!ch.try_send(3).unwrap()); // full
        assert_eq!(ch.recv(), Some(1));
        assert!(ch.try_send(3).unwrap());
    }

    #[test]
    fn close_wakes_a_sender_blocked_on_a_full_channel() {
        // the pipeline's shutdown cascade depends on this: a producer
        // stage blocked on a full inter-stage queue must observe
        // close() and get its item back, not sleep forever
        let ch = Channel::new(1);
        ch.send(1).unwrap();
        let ch2 = ch.clone();
        let t = std::thread::spawn(move || ch2.send(2));
        std::thread::sleep(std::time::Duration::from_millis(20));
        ch.close();
        assert_eq!(t.join().unwrap(), Err(SendError::Closed(2)));
        // the queued item still drains after close
        assert_eq!(ch.recv(), Some(1));
        assert_eq!(ch.recv(), None);
    }

    #[test]
    fn bounded_send_blocks_until_recv() {
        let ch = Channel::new(1);
        ch.send(1).unwrap();
        let ch2 = ch.clone();
        let t = std::thread::spawn(move || ch2.send(2).unwrap());
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(ch.recv(), Some(1));
        t.join().unwrap();
        assert_eq!(ch.recv(), Some(2));
    }

    #[test]
    fn try_recv_never_blocks() {
        let ch: Channel<u32> = Channel::new(0);
        assert_eq!(ch.try_recv(), Ok(None)); // empty, open
        ch.send(7).unwrap();
        assert_eq!(ch.try_recv(), Ok(Some(7)));
        ch.send(8).unwrap();
        ch.close();
        assert_eq!(ch.try_recv(), Ok(Some(8))); // closed but not drained
        assert_eq!(ch.try_recv(), Err(())); // closed and drained
    }

    #[test]
    fn recv_timeout_times_out() {
        let ch: Channel<u32> = Channel::new(0);
        let r = ch.recv_timeout(std::time::Duration::from_millis(10));
        assert_eq!(r, Ok(None));
    }

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<u64> = (0..500).collect();
        let out = par_map(&items, |i, &x| {
            assert_eq!(i as u64, x);
            x * 2
        });
        assert_eq!(out, (0..500).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn channel_mpmc_many_producers_consumers() {
        let ch = Channel::new(16);
        let total = Arc::new(AtomicU64::new(0));
        std::thread::scope(|s| {
            for p in 0..4 {
                let ch = ch.clone();
                s.spawn(move || {
                    for i in 0..100u64 {
                        ch.send(p * 100 + i).unwrap();
                    }
                });
            }
            let consumers: Vec<_> = (0..3)
                .map(|_| {
                    let ch = ch.clone();
                    let total = Arc::clone(&total);
                    s.spawn(move || {
                        while let Some(v) = ch.recv() {
                            total.fetch_add(v, Ordering::Relaxed);
                        }
                    })
                })
                .collect();
            // close after producers are done
            std::thread::sleep(std::time::Duration::from_millis(100));
            ch.close();
            for c in consumers {
                c.join().unwrap();
            }
        });
        let expect: u64 = (0..4u64).map(|p| (0..100).map(|i| p * 100 + i).sum::<u64>()).sum();
        assert_eq!(total.load(Ordering::Relaxed), expect);
    }
}

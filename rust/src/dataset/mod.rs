//! Dataset loading + the hardware input pipeline (feature reduction and
//! 7-bit quantization), matching the build-time python exactly.

use crate::util::idx;
use anyhow::{Context, Result};
use std::path::Path;

/// Number of reduced input features (the paper's 62-node input layer).
pub const N_FEATURES: usize = 62;

/// A loaded, reduced, quantized evaluation set.
pub struct Dataset {
    /// Sign-magnitude encoded features, sign bit always 0: (n, 62).
    pub features: Vec<[u8; N_FEATURES]>,
    pub labels: Vec<u8>,
    /// The frozen 784 -> 62 pixel wiring.
    pub feature_indices: Vec<usize>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Load the test set from an artifacts directory.
    pub fn load_test(artifacts: &Path) -> Result<Dataset> {
        Self::load(
            &artifacts.join("test-images.idx3"),
            &artifacts.join("test-labels.idx1"),
            &artifacts.join("feature-indices.txt"),
        )
    }

    /// Load the training set from an artifacts directory.
    pub fn load_train(artifacts: &Path) -> Result<Dataset> {
        Self::load(
            &artifacts.join("train-images.idx3"),
            &artifacts.join("train-labels.idx1"),
            &artifacts.join("feature-indices.txt"),
        )
    }

    pub fn load(images: &Path, labels: &Path, feat_idx: &Path) -> Result<Dataset> {
        let images = idx::read_images(images).context("loading images")?;
        let labels = idx::read_labels(labels).context("loading labels")?;
        anyhow::ensure!(
            images.n == labels.len(),
            "image/label count mismatch: {} vs {}",
            images.n,
            labels.len()
        );
        let feature_indices = load_feature_indices(feat_idx)?;
        let features = (0..images.n)
            .map(|i| reduce_and_quantize(images.image(i), &feature_indices))
            .collect();
        Ok(Dataset {
            features,
            labels,
            feature_indices,
        })
    }
}

/// Parse `feature-indices.txt` (one index per line).
pub fn load_feature_indices(path: &Path) -> Result<Vec<usize>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let idxs: Vec<usize> = text
        .split_whitespace()
        .map(|t| t.parse::<usize>().context("bad feature index"))
        .collect::<Result<_>>()?;
    anyhow::ensure!(
        idxs.len() == N_FEATURES,
        "expected {N_FEATURES} feature indices, got {}",
        idxs.len()
    );
    Ok(idxs)
}

/// The hardware input stage: select the 62 wired pixels and quantize each
/// uint8 pixel to a 7-bit magnitude (pixel >> 1), sign bit 0.
pub fn reduce_and_quantize(image: &[u8], indices: &[usize]) -> [u8; N_FEATURES] {
    let mut out = [0u8; N_FEATURES];
    for (slot, &pix) in indices.iter().enumerate() {
        out[slot] = image[pix] >> 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reduce_and_quantize_picks_and_shifts() {
        let mut img = vec![0u8; 784];
        img[10] = 255;
        img[20] = 128;
        img[30] = 1;
        let mut indices = vec![0usize; N_FEATURES];
        indices[0] = 10;
        indices[1] = 20;
        indices[2] = 30;
        let out = reduce_and_quantize(&img, &indices);
        assert_eq!(out[0], 127);
        assert_eq!(out[1], 64);
        assert_eq!(out[2], 0);
        assert_eq!(out[3], 0);
        // sign bit never set
        assert!(out.iter().all(|&v| v < 0x80));
    }

    #[test]
    fn feature_indices_parse_and_validate() {
        let dir = std::env::temp_dir().join("ecmac_ds_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("feat.txt");
        let body: String = (0..N_FEATURES).map(|i| format!("{i}\n")).collect();
        std::fs::write(&p, body).unwrap();
        let idxs = load_feature_indices(&p).unwrap();
        assert_eq!(idxs.len(), N_FEATURES);
        assert_eq!(idxs[5], 5);

        std::fs::write(&p, "1 2 3").unwrap();
        assert!(load_feature_indices(&p).is_err());
    }
}

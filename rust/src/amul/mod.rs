//! Bit-exact model of the error-configurable approximate multiplier.
//!
//! This is the rust twin of the frozen spec in
//! `python/compile/kernels/amul_spec.py`; the `golden_parity`
//! integration test cross-checks it against vectors generated from the
//! python side, and the datapath simulator uses it for every MAC
//! operation.
//!
//! The multiplier is a 7x7 unsigned array (operands are 8-bit
//! sign-magnitude; the sign is one XOR handled outside the array) with
//! 13 partial-product columns.  A configuration in `0..=32` selects how
//! each column is compressed:
//!
//! * level 0 — exact adder tree,
//! * level 1 — pairwise-OR approximate compressors (half the adders),
//! * level 2 — full-OR carry-disregarding compression (no adders).
//!
//! Config 0 is exact; config `c >= 1` decodes mask `c - 1` per the
//! frozen decoder (`column_levels`).  Higher mask bits gate wider
//! columns, which is what makes the configuration a power knob.

pub mod metrics;

/// Magnitude bits per operand.
pub const N_BITS: u32 = 7;
/// Maximum operand magnitude (127).
pub const MAG_MAX: u32 = (1 << N_BITS) - 1;
/// Number of partial-product columns.
pub const N_COLS: usize = 2 * N_BITS as usize - 1;
/// Total number of configurations (accurate + 32 approximate).
pub const N_CONFIGS: usize = 33;

/// A validated multiplier configuration (0 = accurate, 1..=32 approximate).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Config(u8);

impl Config {
    pub const ACCURATE: Config = Config(0);
    pub const MAX_APPROX: Config = Config(32);

    pub fn new(cfg: u32) -> Option<Config> {
        (cfg < N_CONFIGS as u32).then_some(Config(cfg as u8))
    }

    pub fn index(self) -> usize {
        self.0 as usize
    }

    pub fn is_accurate(self) -> bool {
        self.0 == 0
    }

    /// All 33 configurations, accurate first.
    pub fn all() -> impl Iterator<Item = Config> {
        (0..N_CONFIGS as u32).map(|c| Config(c as u8))
    }

    /// The 32 approximate configurations.
    pub fn approximate() -> impl Iterator<Item = Config> {
        (1..N_CONFIGS as u32).map(|c| Config(c as u8))
    }
}

impl std::fmt::Display for Config {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.is_accurate() {
            write!(f, "cfg0(accurate)")
        } else {
            write!(f, "cfg{}", self.0)
        }
    }
}

/// A per-layer assignment of multiplier configurations — the schedule
/// the hardware's config register walks as the FSM advances through the
/// layers of one image.
///
/// `Uniform` is the paper's global knob (one configuration for the whole
/// network) and is the fast path everywhere: the functional forward pass
/// hoists a single product table, the PJRT backend can ship the batch to
/// the AOT executable, and the golden vectors stay bit-identical.
/// `PerLayer` is the finer knob from the related work (per-layer
/// approximation tuning): layer `l` runs `cfgs[l]`.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum ConfigSchedule {
    /// One configuration for every layer.
    Uniform(Config),
    /// One configuration per layer (index = layer).
    PerLayer(Vec<Config>),
}

impl ConfigSchedule {
    /// Uniform schedule over `cfg`.
    pub fn uniform(cfg: Config) -> ConfigSchedule {
        ConfigSchedule::Uniform(cfg)
    }

    /// Per-layer schedule.  The declared layer count is preserved even
    /// when every entry is equal, so [`ConfigSchedule::validate`] can
    /// still catch a length mismatch; the fast paths see through
    /// trivially-uniform schedules via [`ConfigSchedule::as_uniform`].
    pub fn per_layer(cfgs: Vec<Config>) -> ConfigSchedule {
        assert!(!cfgs.is_empty(), "schedule needs at least one layer");
        ConfigSchedule::PerLayer(cfgs)
    }

    /// The configuration layer `l` runs.  Per-layer schedules clamp to
    /// their last entry so a schedule built for a shallower prefix still
    /// yields a defined configuration (validated separately).
    #[inline]
    pub fn layer(&self, l: usize) -> Config {
        match self {
            ConfigSchedule::Uniform(c) => *c,
            ConfigSchedule::PerLayer(v) => v[l.min(v.len() - 1)],
        }
    }

    /// `Some(cfg)` when every layer runs the same configuration —
    /// including a `PerLayer` schedule whose entries are all equal, so
    /// the uniform fast paths (single product table, PJRT executable,
    /// per-config metrics) apply whenever they semantically can.
    pub fn as_uniform(&self) -> Option<Config> {
        match self {
            ConfigSchedule::Uniform(c) => Some(*c),
            ConfigSchedule::PerLayer(v) => {
                let c = v[0];
                v.iter().all(|&x| x == c).then_some(c)
            }
        }
    }

    /// Explicit per-layer configuration vector for a network with
    /// `n_layers` weight layers (uniform schedules fan out, per-layer
    /// schedules clamp like [`ConfigSchedule::layer`]).  The frontier
    /// search and reports use this to compare schedules element-wise.
    pub fn resolve(&self, n_layers: usize) -> Vec<Config> {
        (0..n_layers).map(|l| self.layer(l)).collect()
    }

    /// Number of layers the schedule names explicitly (None = uniform).
    pub fn n_layers(&self) -> Option<usize> {
        match self {
            ConfigSchedule::Uniform(_) => None,
            ConfigSchedule::PerLayer(v) => Some(v.len()),
        }
    }

    /// Check the schedule fits a network with `n_layers` weight layers.
    pub fn validate(&self, n_layers: usize) -> anyhow::Result<()> {
        if let ConfigSchedule::PerLayer(v) = self {
            anyhow::ensure!(
                v.len() == n_layers,
                "schedule names {} layers but the network has {n_layers}",
                v.len()
            );
        }
        Ok(())
    }

    /// Parse `"9"` (uniform) or `"0,9,17"` (per-layer) — the CLI's
    /// `--schedule` syntax.  A multi-entry spec stays `PerLayer` even
    /// when all entries are equal, so `validate` still catches a layer
    /// count that does not match the network.
    pub fn parse(s: &str) -> anyhow::Result<ConfigSchedule> {
        let cfgs: Vec<Config> = s
            .split(',')
            .map(|t| {
                t.trim()
                    .parse::<u32>()
                    .ok()
                    .and_then(Config::new)
                    .ok_or_else(|| anyhow::anyhow!("bad config '{t}' (want 0..=32)"))
            })
            .collect::<anyhow::Result<_>>()?;
        anyhow::ensure!(!cfgs.is_empty(), "empty schedule");
        Ok(if cfgs.len() == 1 {
            ConfigSchedule::Uniform(cfgs[0])
        } else {
            ConfigSchedule::PerLayer(cfgs)
        })
    }
}

impl std::fmt::Display for ConfigSchedule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigSchedule::Uniform(c) => write!(f, "{c}"),
            ConfigSchedule::PerLayer(v) => {
                write!(f, "cfg[")?;
                for (i, c) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}", c.index())?;
                }
                write!(f, "]")
            }
        }
    }
}

/// Per-column approximation level for a configuration — the decoder ROM.
///
/// Frozen spec (must match `amul_spec.column_levels`):
/// base `lv[1]=2, lv[2]=1`; mask bit 0 -> col2 +1; bit 1 -> col3 +2;
/// bit 2 -> col4 +2; bit 3 -> col5 +2; bit 4 -> cols 6,7 +1; saturate at 2.
pub fn column_levels(cfg: Config) -> [u8; N_COLS] {
    let mut lv = [0u8; N_COLS];
    if cfg.is_accurate() {
        return lv;
    }
    let m = cfg.0 as u32 - 1;
    lv[1] = 2;
    lv[2] = 1;
    if m & 1 != 0 {
        lv[2] += 1;
    }
    if m & 2 != 0 {
        lv[3] += 2;
    }
    if m & 4 != 0 {
        lv[4] += 2;
    }
    if m & 8 != 0 {
        lv[5] += 2;
    }
    if m & 16 != 0 {
        lv[6] += 1;
        lv[7] += 1;
    }
    for l in lv.iter_mut() {
        *l = (*l).min(2);
    }
    lv
}

/// Partial products of column `k` as (i, j) bit-index pairs, ascending i.
/// The pairwise-OR compressor pairs them in this order.
pub fn column_pps(k: usize) -> impl Iterator<Item = (u32, u32)> {
    (0..N_BITS)
        .filter_map(move |i| {
            let j = k as i32 - i as i32;
            (0..N_BITS as i32).contains(&j).then_some((i, j as u32))
        })
}

/// Approximate 7x7 unsigned multiply (bit-level, straight from the spec).
///
/// Exact for `Config::ACCURATE`. Result is a 14-bit magnitude.
pub fn mul7_approx(a: u32, b: u32, cfg: Config) -> u32 {
    mul7_approx_with_levels(a, b, &column_levels(cfg))
}

/// `mul7_approx` with the decoder output hoisted — callers that sweep an
/// operand space decode the configuration once (DESIGN.md §Perf).
pub fn mul7_approx_with_levels(a: u32, b: u32, levels: &[u8; N_COLS]) -> u32 {
    debug_assert!(a <= MAG_MAX && b <= MAG_MAX);
    let mut total = 0u32;
    for k in 0..N_COLS {
        let mut pps = [0u32; 7];
        let mut n = 0;
        for (i, j) in column_pps(k) {
            pps[n] = (a >> i) & (b >> j) & 1;
            n += 1;
        }
        let contrib = match levels[k] {
            0 => pps[..n].iter().sum::<u32>(),
            1 => {
                let mut c = 0;
                let mut p = 0;
                while p + 1 < n {
                    c += pps[p] | pps[p + 1];
                    p += 2;
                }
                if n % 2 == 1 {
                    c += pps[n - 1];
                }
                c
            }
            _ => pps[..n].iter().fold(0, |acc, &p| acc | p),
        };
        total += contrib << k;
    }
    total
}

/// Sign-magnitude helpers (MSB = sign, low 7 bits = magnitude).
///
/// Re-exported from [`crate::util::signmag`], the single home of the
/// encoding logic shared across the stack.
pub use crate::util::signmag as sm;

/// Approximate signed multiply of 8-bit sign-magnitude encodings.
///
/// The sign is the XOR of the operand signs (the MAC's sign logic);
/// zero magnitudes always produce +0.
pub fn mul8_sm_approx(x: u8, w: u8, cfg: Config) -> i32 {
    let mag = mul7_approx(sm::mag(x), sm::mag(w), cfg) as i32;
    sm::apply_sign(mag, x, w)
}

/// Precomputed 128x128 product table for one configuration.
///
/// The datapath simulator's hot path is table-driven: one lookup per
/// MAC instead of 13 column reductions.  16 KiB per config (u16).
pub struct MulTable {
    pub cfg: Config,
    table: Vec<u16>, // [a * 128 + b] -> approximate product
}

impl MulTable {
    pub fn build(cfg: Config) -> MulTable {
        let levels = column_levels(cfg);
        let mut table = vec![0u16; 128 * 128];
        for a in 0..=MAG_MAX {
            for b in 0..=MAG_MAX {
                table[(a * 128 + b) as usize] =
                    mul7_approx_with_levels(a, b, &levels) as u16;
            }
        }
        MulTable { cfg, table }
    }

    #[inline(always)]
    pub fn mul7(&self, a: u32, b: u32) -> u32 {
        self.table[(a * 128 + b) as usize] as u32
    }

    /// Signed sign-magnitude multiply through the table.
    #[inline(always)]
    pub fn mul8_sm(&self, x: u8, w: u8) -> i32 {
        let mag = self.mul7(sm::mag(x), sm::mag(w)) as i32;
        sm::apply_sign(mag, x, w)
    }

    /// Row view for a fixed first operand: amortizes the operand decode
    /// across a weight row (the datapath hot loop).
    #[inline(always)]
    pub fn row(&self, x: u8) -> MulRow<'_> {
        let mag = (x & 0x7F) as usize;
        MulRow {
            row: &self.table[mag * 128..mag * 128 + 128],
            x_sign: x & 0x80,
        }
    }
}

/// Precomputed lookup row of `MulTable` for one left operand.
pub struct MulRow<'t> {
    row: &'t [u16],
    x_sign: u8,
}

impl MulRow<'_> {
    /// Signed multiply of the captured operand with `w`.
    ///
    /// Branchless via [`sm::apply_sign`]: a zero magnitude stays +0 and
    /// the sign-XOR semantics hold without a data-dependent branch.
    #[inline(always)]
    pub fn mul8_sm(&self, w: u8) -> i32 {
        let mag = self.row[(w & 0x7F) as usize] as i32;
        sm::apply_sign(mag, self.x_sign, w)
    }
}

/// Per-configuration *signed* product table indexed directly by the two
/// raw sign-magnitude bytes: `row(x)[w] == mul8_sm_approx(x, w, cfg)`.
///
/// This is the functional hot path's kernel (DESIGN.md §Perf): one
/// `i16` gather per MAC, no sign decode, no fixup — the sign XOR is
/// baked into the table at build time, so it is bit-exact with
/// [`mul8_sm_approx`] by construction.  256 rows of 256 `i16`
/// (128 KiB per configuration, ~4 MiB if all 33 ever materialize —
/// they are built lazily per config by [`MulTables::signed`]).  The
/// row type is `[i16; 256]` so indexing with a `u8` operand needs no
/// bounds check.
///
/// The storage carries one trailing all-zero *padding row*: the AVX2
/// tile kernel ([`crate::datapath::gemm`]) gathers 32-bit lanes at
/// `&row[w]` and sign-extends the low 16 bits, so a gather at the last
/// index of the last real row reads 2 bytes past that row's end —
/// [`SignedMulTable::row_ptr`] guarantees those bytes stay inside the
/// allocation.
pub struct SignedMulTable {
    pub cfg: Config,
    /// 256 real rows + 1 zero padding row (see the type-level docs).
    rows: Vec<[i16; 256]>,
}

impl SignedMulTable {
    /// Build from the configuration's magnitude table (the 64Ki entries
    /// are four sign-quadrant images of the 128x128 magnitude table).
    pub fn build(mag: &MulTable) -> SignedMulTable {
        let mut rows = vec![[0i16; 256]; 257];
        for (x, row) in rows.iter_mut().take(256).enumerate() {
            for (w, out) in row.iter_mut().enumerate() {
                let m = mag.mul7(x as u32 & 0x7F, w as u32 & 0x7F) as i32;
                // max |product| is 127*127 = 16129, well inside i16
                *out = sm::apply_sign(m, x as u8, w as u8) as i16;
            }
        }
        if crate::chaos::enabled() {
            // SEU model: the table SRAM holds the fault from load time
            crate::chaos::on_table_build(mag.cfg, &mut rows);
        }
        SignedMulTable { cfg: mag.cfg, rows }
    }

    /// The 256-entry signed product row for left operand byte `x`;
    /// index it with the raw weight byte.
    #[inline(always)]
    pub fn row(&self, x: u8) -> &[i16; 256] {
        &self.rows[x as usize]
    }

    /// Raw pointer to the product row of `x`, derived from the whole
    /// table allocation, with a guarantee the SIMD kernels rely on:
    /// at least 2 readable bytes follow every row's end (the next row,
    /// or the trailing zero padding row after row 255), so a 32-bit
    /// gather at any in-row `i16` stays inside the allocation.
    #[inline(always)]
    pub fn row_ptr(&self, x: u8) -> *const i16 {
        debug_assert_eq!(self.rows.len(), 257, "padding row missing");
        // in-bounds: x * 256 < 257 * 256 elements
        unsafe { (self.rows.as_ptr() as *const i16).add(x as usize * 256) }
    }

    /// Signed multiply of two raw sign-magnitude bytes.
    #[inline(always)]
    pub fn mul8_sm(&self, x: u8, w: u8) -> i32 {
        self.rows[x as usize][w as usize] as i32
    }

    /// Stored row count (256 real rows + the trailing padding row) —
    /// the gather-bound invariant `row_ptr` relies on, re-verified per
    /// configuration by the static analyzer (`analysis::range`).
    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// The trailing padding row (must be identically zero so the AVX2
    /// 2-byte row-end overread reads zeros).
    pub fn padding_row(&self) -> &[i16; 256] {
        &self.rows[256]
    }

    /// FNV-1a 64 fingerprint over every stored row, padding included —
    /// the sentinel scrubber's integrity digest.  Any single bit flip
    /// anywhere in the modeled table SRAM changes the value, and the
    /// walk is deterministic, so a digest recorded at build time can be
    /// re-verified between batch windows.
    pub fn digest(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for row in &self.rows {
            for &v in row.iter() {
                for b in (v as u16).to_le_bytes() {
                    h ^= b as u64;
                    h = h.wrapping_mul(0x100_0000_01b3);
                }
            }
        }
        h
    }

    /// A copy with one product bit flipped at (`x`, `w`) — the
    /// fault-injection primitive behind
    /// [`crate::chaos::poison_resident_table`] and the sentinel drills.
    pub fn corrupted_copy(&self, x: u8, w: u8, bit: u8) -> SignedMulTable {
        let mut rows = self.rows.clone();
        rows[x as usize][w as usize] ^= 1i16 << (bit & 15);
        SignedMulTable { cfg: self.cfg, rows }
    }
}

/// Lazy per-configuration table store: magnitude tables (16 KiB each)
/// and signed tables (128 KiB each) materialize on first use, so
/// uniform-schedule serving and CLI startup only ever build the
/// configurations they actually run.
///
/// Signed tables sit behind per-slot atomic pointers rather than
/// `OnceLock` so the sentinel scrubber can *swap a rebuilt table into a
/// live store* ([`MulTables::replace_signed`]) while worker threads
/// hold references from [`MulTables::signed`].  Displaced tables are
/// retired, not freed: a returned reference borrows `self`, so retired
/// tables only drop when the store does.  A scrub swap is rare (one per
/// detected corruption), so the retired list stays tiny.
pub struct MulTables {
    mag: [std::sync::OnceLock<MulTable>; N_CONFIGS],
    signed: [std::sync::atomic::AtomicPtr<SignedMulTable>; N_CONFIGS],
    retired: std::sync::Mutex<Vec<*mut SignedMulTable>>,
}

// Safety: every pointer in `signed`/`retired` is a private Box
// allocation published with Release and read with Acquire, and
// displaced tables are freed only in `drop(&mut self)` — after every
// `&self`-lifetime borrow has ended.
unsafe impl Send for MulTables {}
unsafe impl Sync for MulTables {}

impl Default for MulTables {
    fn default() -> Self {
        Self::build()
    }
}

impl Drop for MulTables {
    fn drop(&mut self) {
        use std::sync::atomic::Ordering;
        for slot in &self.signed {
            let p = slot.swap(std::ptr::null_mut(), Ordering::AcqRel);
            if !p.is_null() {
                drop(unsafe { Box::from_raw(p) });
            }
        }
        let retired = self.retired.get_mut().unwrap_or_else(|e| e.into_inner());
        for p in retired.drain(..) {
            drop(unsafe { Box::from_raw(p) });
        }
    }
}

impl MulTables {
    /// The lazy store (nothing is computed here; the name is kept from
    /// the eager era for caller compatibility).
    pub fn build() -> MulTables {
        MulTables {
            mag: std::array::from_fn(|_| std::sync::OnceLock::new()),
            signed: std::array::from_fn(|_| {
                std::sync::atomic::AtomicPtr::new(std::ptr::null_mut())
            }),
            retired: std::sync::Mutex::new(Vec::new()),
        }
    }

    /// The configuration's magnitude table, built on first use.
    pub fn get(&self, cfg: Config) -> &MulTable {
        self.mag[cfg.index()].get_or_init(|| MulTable::build(cfg))
    }

    /// The configuration's signed table, built on first use.
    pub fn signed(&self, cfg: Config) -> &SignedMulTable {
        use std::sync::atomic::Ordering;
        let slot = &self.signed[cfg.index()];
        let p = slot.load(Ordering::Acquire);
        if !p.is_null() {
            return unsafe { &*p };
        }
        let fresh = Box::into_raw(Box::new(SignedMulTable::build(self.get(cfg))));
        match slot.compare_exchange(
            std::ptr::null_mut(),
            fresh,
            Ordering::AcqRel,
            Ordering::Acquire,
        ) {
            Ok(_) => unsafe { &*fresh },
            Err(winner) => {
                // another thread published first; ours was never shared
                drop(unsafe { Box::from_raw(fresh) });
                unsafe { &*winner }
            }
        }
    }

    /// The configuration's signed table only if already materialized —
    /// the scrubber digests resident tables without forcing absent
    /// ones into existence.
    pub fn signed_if_built(&self, cfg: Config) -> Option<&SignedMulTable> {
        let p = self.signed[cfg.index()].load(std::sync::atomic::Ordering::Acquire);
        (!p.is_null()).then(|| unsafe { &*p })
    }

    /// Rebuild the configuration's signed table from its magnitude
    /// table — the scrubber's "reload from ROM" step.  Nothing is
    /// installed; pair with [`MulTables::replace_signed`] after the
    /// rebuilt table re-validates against the `analysis::range`
    /// envelopes.  (An active chaos fault plan still applies: a
    /// persistent SRAM fault re-poisons the reload, which is exactly
    /// what forces the pin-accurate branch.)
    pub fn rebuild_signed(&self, cfg: Config) -> SignedMulTable {
        SignedMulTable::build(self.get(cfg))
    }

    /// Swap a freshly built signed table into the live store.  The
    /// displaced table (if any) is retired until the store drops, so
    /// references already handed out by [`MulTables::signed`] stay
    /// valid; new lookups see the replacement.  Returns whether a
    /// resident table was displaced (false = the slot was empty and
    /// the new table simply materialized it).
    pub fn replace_signed(&self, table: SignedMulTable) -> bool {
        use std::sync::atomic::Ordering;
        let idx = table.cfg.index();
        let fresh = Box::into_raw(Box::new(table));
        let old = self.signed[idx].swap(fresh, Ordering::AcqRel);
        if old.is_null() {
            return false;
        }
        self.retired
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .push(old);
        true
    }

    /// Number of magnitude tables materialized so far (observability +
    /// laziness tests).
    pub fn built(&self) -> usize {
        self.mag.iter().filter(|c| c.get().is_some()).count()
    }

    /// Number of signed tables materialized so far — what the prewarm
    /// tests assert, since the hot paths (gemm tiles, the pipelined
    /// stages) gather exclusively from the signed tables.
    pub fn signed_built(&self) -> usize {
        self.signed
            .iter()
            .filter(|s| !s.load(std::sync::atomic::Ordering::Acquire).is_null())
            .count()
    }

    /// Materialize the signed (and, transitively, magnitude) tables of
    /// every configuration `sched` runs.  Lazy `OnceLock` init is the
    /// right default for CLI one-shots, but it puts the table build
    /// (~ms per configuration) on the first request that needs it —
    /// `serve` startup and every timed bench region call this first so
    /// no request or measured iteration pays it.
    pub fn prewarm(&self, sched: &ConfigSchedule) {
        match sched {
            ConfigSchedule::Uniform(c) => {
                self.signed(*c);
            }
            ConfigSchedule::PerLayer(v) => {
                let mut seen = [false; N_CONFIGS];
                for &c in v {
                    if !std::mem::replace(&mut seen[c.index()], true) {
                        self.signed(c);
                    }
                }
            }
        }
    }

}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_validation() {
        assert!(Config::new(0).is_some());
        assert!(Config::new(32).is_some());
        assert!(Config::new(33).is_none());
        assert_eq!(Config::all().count(), 33);
        assert_eq!(Config::approximate().count(), 32);
    }

    #[test]
    fn decoder_cfg0_exact() {
        assert_eq!(column_levels(Config::ACCURATE), [0u8; N_COLS]);
    }

    #[test]
    fn decoder_cfg1_base() {
        let lv = column_levels(Config::new(1).unwrap());
        assert_eq!(lv[1], 2);
        assert_eq!(lv[2], 1);
        assert!(lv.iter().enumerate().all(|(k, &l)| l == 0 || k == 1 || k == 2));
    }

    #[test]
    fn decoder_cfg32_max() {
        let lv = column_levels(Config::MAX_APPROX);
        assert_eq!(lv, [0, 2, 2, 2, 2, 2, 1, 1, 0, 0, 0, 0, 0]);
    }

    #[test]
    fn cfg0_is_exact_exhaustive() {
        for a in 0..=MAG_MAX {
            for b in 0..=MAG_MAX {
                assert_eq!(mul7_approx(a, b, Config::ACCURATE), a * b);
            }
        }
    }

    #[test]
    fn approx_never_exceeds_exact() {
        for cfg in Config::approximate() {
            for a in (0..=MAG_MAX).step_by(3) {
                for b in (0..=MAG_MAX).step_by(5) {
                    assert!(mul7_approx(a, b, cfg) <= a * b, "{cfg} {a} {b}");
                }
            }
        }
    }

    #[test]
    fn zero_annihilates() {
        for cfg in Config::all() {
            for v in [0u32, 1, 63, 127] {
                assert_eq!(mul7_approx(0, v, cfg), 0);
                assert_eq!(mul7_approx(v, 0, cfg), 0);
            }
        }
    }

    #[test]
    fn commutative_in_accurate_mode() {
        for a in (0..=MAG_MAX).step_by(3) {
            for b in (0..=MAG_MAX).step_by(5) {
                assert_eq!(
                    mul7_approx(a, b, Config::ACCURATE),
                    mul7_approx(b, a, Config::ACCURATE)
                );
            }
        }
    }

    #[test]
    fn pairwise_or_levels_are_not_commutative() {
        // The level-1 compressor pairs partial products in i-order, so
        // odd-sized columns break operand symmetry — a documented
        // property of the hardware (operand roles are fixed: x =
        // activation, w = weight).  This test locks the asymmetry so an
        // accidental "fix" on one side of the stack gets caught.
        let cfg = Config::new(1).unwrap(); // col2 at level 1 (3 pps)
        let mut asym = 0;
        for a in 0..=MAG_MAX {
            for b in 0..=MAG_MAX {
                if mul7_approx(a, b, cfg) != mul7_approx(b, a, cfg) {
                    asym += 1;
                }
            }
        }
        assert!(asym > 0, "expected operand-order asymmetry at level 1");
        // full-OR (level 2) columns are symmetric: check max config on
        // level-2-only columns via a targeted example
        let cfg32 = Config::MAX_APPROX;
        let mut asym32 = 0;
        for a in 0..=MAG_MAX {
            for b in 0..=MAG_MAX {
                if mul7_approx(a, b, cfg32) != mul7_approx(b, a, cfg32) {
                    asym32 += 1;
                }
            }
        }
        // cfg32 still has level-1 columns (6, 7), so asymmetry remains
        assert!(asym32 > 0);
    }

    #[test]
    fn sm_roundtrip() {
        for v in -127..=127 {
            assert_eq!(sm::decode(sm::encode(v)), v);
        }
    }

    #[test]
    fn signed_mul_cfg0() {
        for x in (-127..=127).step_by(13) {
            for w in (-127..=127).step_by(17) {
                assert_eq!(
                    mul8_sm_approx(sm::encode(x), sm::encode(w), Config::ACCURATE),
                    x * w
                );
            }
        }
    }

    #[test]
    fn sign_symmetry() {
        for cfg in [Config::ACCURATE, Config::new(9).unwrap(), Config::MAX_APPROX] {
            let p = mul8_sm_approx(sm::encode(100), sm::encode(55), cfg);
            assert_eq!(mul8_sm_approx(sm::encode(-100), sm::encode(55), cfg), -p);
            assert_eq!(mul8_sm_approx(sm::encode(100), sm::encode(-55), cfg), -p);
            assert_eq!(mul8_sm_approx(sm::encode(-100), sm::encode(-55), cfg), p);
        }
    }

    #[test]
    fn negative_zero_is_plus_zero() {
        assert_eq!(mul8_sm_approx(0x80, sm::encode(99), Config::ACCURATE), 0);
    }

    #[test]
    fn table_matches_direct() {
        for cfg in [Config::ACCURATE, Config::new(7).unwrap(), Config::MAX_APPROX] {
            let t = MulTable::build(cfg);
            for a in 0..=MAG_MAX {
                for b in 0..=MAG_MAX {
                    assert_eq!(t.mul7(a, b), mul7_approx(a, b, cfg));
                }
            }
        }
    }

    #[test]
    fn schedule_uniform_semantics_and_lookup() {
        let c9 = Config::new(9).unwrap();
        let c17 = Config::new(17).unwrap();
        // a trivially-uniform per-layer schedule keeps its layer count
        // (validate still works) but exposes the uniform fast path
        let triv = ConfigSchedule::per_layer(vec![c9, c9, c9]);
        assert_eq!(triv.as_uniform(), Some(c9));
        assert_eq!(triv.n_layers(), Some(3));
        assert!(triv.validate(3).is_ok());
        assert!(triv.validate(2).is_err(), "wrong layer count must not be hidden");
        let s = ConfigSchedule::per_layer(vec![Config::ACCURATE, c9, c17]);
        assert_eq!(s.as_uniform(), None);
        assert_eq!(s.layer(0), Config::ACCURATE);
        assert_eq!(s.layer(1), c9);
        assert_eq!(s.layer(2), c17);
        // clamps past the end
        assert_eq!(s.layer(9), c17);
        assert!(s.validate(3).is_ok());
        assert!(s.validate(2).is_err());
        // uniform validates against any depth
        assert!(ConfigSchedule::uniform(c9).validate(7).is_ok());
        // resolve fans uniform out and echoes per-layer vectors
        assert_eq!(ConfigSchedule::uniform(c9).resolve(3), vec![c9, c9, c9]);
        assert_eq!(s.resolve(3), vec![Config::ACCURATE, c9, c17]);
    }

    #[test]
    fn schedule_parse_roundtrip() {
        assert_eq!(
            ConfigSchedule::parse("9").unwrap(),
            ConfigSchedule::uniform(Config::new(9).unwrap())
        );
        let s = ConfigSchedule::parse("0, 9,17").unwrap();
        assert_eq!(s.n_layers(), Some(3));
        assert!(ConfigSchedule::parse("33").is_err());
        assert!(ConfigSchedule::parse("x").is_err());
        assert_eq!(format!("{s}"), "cfg[0,9,17]");
        assert_eq!(
            format!("{}", ConfigSchedule::uniform(Config::ACCURATE)),
            "cfg0(accurate)"
        );
        // an all-equal multi-entry spec keeps its length for validation
        let same = ConfigSchedule::parse("5,5,5").unwrap();
        assert_eq!(same.n_layers(), Some(3));
        assert_eq!(same.as_uniform(), Some(Config::new(5).unwrap()));
        assert!(same.validate(2).is_err());
    }

    #[test]
    fn tables_signed_path() {
        let tabs = MulTables::build();
        let t = tabs.get(Config::new(5).unwrap());
        for x in (-127i32..=127).step_by(31) {
            for w in (-127i32..=127).step_by(29) {
                assert_eq!(
                    t.mul8_sm(sm::encode(x), sm::encode(w)),
                    mul8_sm_approx(sm::encode(x), sm::encode(w), Config::new(5).unwrap())
                );
            }
        }
    }

    #[test]
    fn signed_table_exhaustive_parity_key_configs() {
        // every (x, w) byte pair, including negative zeros, for the
        // exact config, a mid config and the worst config — the signed
        // table must reproduce mul8_sm_approx bit for bit
        let tabs = MulTables::build();
        for cfg in [Config::ACCURATE, Config::new(7).unwrap(), Config::MAX_APPROX] {
            let st = tabs.signed(cfg);
            assert_eq!(st.cfg, cfg);
            for x in 0..=255u8 {
                let row = st.row(x);
                for w in 0..=255u8 {
                    let want = mul8_sm_approx(x, w, cfg);
                    assert_eq!(st.mul8_sm(x, w), want, "{cfg} x={x:#04x} w={w:#04x}");
                    assert_eq!(row[w as usize] as i32, want);
                }
            }
        }
    }

    #[test]
    fn signed_table_zero_magnitude_rows_are_all_zero() {
        // the hot loop skips zero-magnitude activations; that is only
        // bit-exact if 0 and -0 rows (and columns) are identically zero
        // — for every configuration the skip can run under
        for cfg in Config::all() {
            let st = SignedMulTable::build(&MulTable::build(cfg));
            for w in 0..=255u8 {
                assert_eq!(st.mul8_sm(0x00, w), 0, "{cfg}");
                assert_eq!(st.mul8_sm(0x80, w), 0, "{cfg}");
                assert_eq!(st.mul8_sm(w, 0x00), 0, "{cfg}");
                assert_eq!(st.mul8_sm(w, 0x80), 0, "{cfg}");
            }
        }
    }

    #[test]
    fn signed_table_row_ptr_matches_row_and_padding_is_zero() {
        let st = SignedMulTable::build(&MulTable::build(Config::new(11).unwrap()));
        for x in [0u8, 1, 0x7F, 0x80, 0xFE, 0xFF] {
            let row = st.row(x);
            let p = st.row_ptr(x);
            for w in 0..256usize {
                assert_eq!(unsafe { *p.add(w) }, row[w], "x={x:#04x} w={w}");
            }
            // the 2 bytes past the row's end are inside the allocation:
            // row 255 is followed by the all-zero padding row
            if x == 0xFF {
                assert_eq!(unsafe { *p.add(256) }, 0, "padding row must be zero");
            }
        }
    }

    #[test]
    fn row_ptr_overread_stays_in_allocation() {
        // The Stacked-Borrows claim the AVX2 gather depends on: row
        // pointers derive from the *whole* 257-row allocation, so the
        // 2-byte read past any row's end — the next row, or the zero
        // padding row after row 255 — is in-bounds under the same
        // provenance.  Run under Miri (the CI lane) this is a proof,
        // not a smoke test: a per-row reborrow in `row_ptr` would fail
        // here with an out-of-bounds/expired-tag error.
        let st = SignedMulTable::build(&MulTable::build(Config::MAX_APPROX));
        for x in [0u8, 1, 127, 128, 255] {
            let p = st.row_ptr(x);
            // last element of the row, then one element past its end
            let last = unsafe { p.add(255).read_unaligned() };
            assert_eq!(last as i32, st.mul8_sm(x, 255), "x={x}");
            let over = unsafe { p.add(256).read_unaligned() };
            let want = if x == 255 { 0 } else { st.mul8_sm(x + 1, 0) };
            assert_eq!(over as i32, want, "x={x} overread");
        }
    }

    #[test]
    fn prewarm_builds_exactly_the_schedule_configs() {
        let tabs = MulTables::build();
        assert_eq!(tabs.built(), 0);
        let c9 = Config::new(9).unwrap();
        let c17 = Config::new(17).unwrap();
        // duplicates collapse; distinct configs each materialize once
        tabs.prewarm(&ConfigSchedule::per_layer(vec![c9, c17, c9]));
        assert_eq!(tabs.built(), 2);
        tabs.prewarm(&ConfigSchedule::uniform(Config::ACCURATE));
        assert_eq!(tabs.built(), 3);
        // idempotent
        tabs.prewarm(&ConfigSchedule::uniform(c9));
        assert_eq!(tabs.built(), 3);
    }

    #[test]
    fn tables_build_lazily_per_config() {
        let tabs = MulTables::build();
        assert_eq!(tabs.built(), 0, "construction must not materialize tables");
        let c9 = Config::new(9).unwrap();
        let t1 = tabs.get(c9) as *const MulTable;
        assert_eq!(tabs.built(), 1);
        // repeated lookups return the same materialized table
        let t2 = tabs.get(c9) as *const MulTable;
        assert_eq!(t1, t2);
        // the signed table reuses the magnitude table of its config
        let _ = tabs.signed(Config::MAX_APPROX);
        assert_eq!(tabs.built(), 2);
        assert_eq!(tabs.built(), 2);
    }

    #[test]
    fn signed_digest_detects_single_bit_flip() {
        let tabs = MulTables::build();
        let cfg = Config::new(9).unwrap();
        let t = tabs.signed(cfg);
        let clean = t.digest();
        // digesting is a pure read: repeatable, no state
        assert_eq!(clean, t.digest());
        let poisoned = t.corrupted_copy(33, 77, 4);
        assert_ne!(clean, poisoned.digest());
        // the flip lands where asked and nowhere else
        assert_ne!(t.mul8_sm(33, 77), poisoned.mul8_sm(33, 77));
        assert_eq!(t.mul8_sm(12, 200), poisoned.mul8_sm(12, 200));
        assert_eq!(t.mul8_sm(255, 255), poisoned.mul8_sm(255, 255));
    }

    #[test]
    fn replace_signed_swaps_live_and_keeps_old_refs_valid() {
        let tabs = MulTables::build();
        let cfg = Config::new(3).unwrap();
        let before = tabs.signed(cfg);
        let v = before.mul8_sm(5, 7);
        assert!(tabs.replace_signed(before.corrupted_copy(5, 7, 0)));
        // the retired table is still readable through the old reference
        assert_eq!(before.mul8_sm(5, 7), v);
        // fresh lookups see the replacement
        assert_ne!(tabs.signed(cfg).mul8_sm(5, 7), v);
        // rebuild-from-ROM restores the clean bits end to end
        let rebuilt = tabs.rebuild_signed(cfg);
        assert!(tabs.replace_signed(rebuilt));
        assert_eq!(tabs.signed(cfg).mul8_sm(5, 7), v);
        assert_eq!(tabs.signed_built(), 1, "a swap is not a new slot");
    }

    #[test]
    fn signed_if_built_does_not_materialize() {
        let tabs = MulTables::build();
        let cfg = Config::new(2).unwrap();
        assert!(tabs.signed_if_built(cfg).is_none());
        assert_eq!(tabs.signed_built(), 0);
        tabs.signed(cfg);
        assert!(tabs.signed_if_built(cfg).is_some());
        assert_eq!(tabs.signed_built(), 1);
        // replacing into an empty slot materializes without retiring
        let other = MulTables::build();
        assert!(!other.replace_signed(tabs.rebuild_signed(cfg)));
        assert_eq!(other.signed_built(), 1);
    }
}

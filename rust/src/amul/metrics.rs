//! Error metrics for approximate arithmetic circuits (Table I).
//!
//! Definitions follow the approximate-computing literature the paper
//! cites (Strollo et al., Yin et al.):
//!
//! * **ER** — error rate: fraction of input pairs whose output differs
//!   from the exact product.
//! * **MRED** — mean relative error distance: mean of |err| / exact over
//!   pairs with a non-zero exact product.
//! * **NMED** — normalized mean error distance: mean |err| divided by
//!   the maximum exact output (127 * 127).
//!
//! All three are computed *exhaustively* over the full 128x128 operand
//! space — the multiplier is small enough that sampling would be
//! malpractice.

use super::{column_levels, mul7_approx_with_levels, Config, MAG_MAX};

/// Exhaustive error statistics of one configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ErrorStats {
    pub cfg: u32,
    pub er_pct: f64,
    pub mred_pct: f64,
    pub nmed_pct: f64,
    /// Worst-case absolute error distance over the operand space.
    pub max_ed: u32,
    /// Mean absolute error distance.
    pub mean_ed: f64,
}

/// Compute exhaustive stats for `cfg`.
pub fn exhaustive(cfg: Config) -> ErrorStats {
    let mut n_err = 0u64;
    let mut sum_ed = 0u64;
    let mut sum_red = 0.0f64;
    let mut n_nonzero = 0u64;
    let mut max_ed = 0u32;
    let levels = column_levels(cfg);
    for a in 0..=MAG_MAX {
        for b in 0..=MAG_MAX {
            let exact = a * b;
            let approx = mul7_approx_with_levels(a, b, &levels);
            let ed = exact - approx; // approximation only loses value
            if ed != 0 {
                n_err += 1;
            }
            sum_ed += ed as u64;
            max_ed = max_ed.max(ed);
            if exact != 0 {
                sum_red += ed as f64 / exact as f64;
                n_nonzero += 1;
            }
        }
    }
    let n = 128u64 * 128;
    ErrorStats {
        cfg: cfg.index() as u32,
        er_pct: n_err as f64 / n as f64 * 100.0,
        mred_pct: sum_red / n_nonzero as f64 * 100.0,
        nmed_pct: sum_ed as f64 / n as f64 / (MAG_MAX * MAG_MAX) as f64 * 100.0,
        max_ed,
        mean_ed: sum_ed as f64 / n as f64,
    }
}

/// Stats for every configuration (accurate first), in parallel.
pub fn full_table() -> Vec<ErrorStats> {
    let configs: Vec<Config> = Config::all().collect();
    crate::util::threadpool::par_map(&configs, |_, &cfg| exhaustive(cfg))
}

/// Aggregate min/max/avg over the 32 approximate configurations —
/// the exact shape of the paper's Table I.
#[derive(Debug, Clone, Copy)]
pub struct TableISummary {
    pub er_min: f64,
    pub er_max: f64,
    pub er_avg: f64,
    pub mred_min: f64,
    pub mred_max: f64,
    pub mred_avg: f64,
    pub nmed_min: f64,
    pub nmed_max: f64,
    pub nmed_avg: f64,
}

pub fn table_i(stats: &[ErrorStats]) -> TableISummary {
    let approx: Vec<&ErrorStats> = stats.iter().filter(|s| s.cfg != 0).collect();
    assert!(!approx.is_empty());
    let n = approx.len() as f64;
    let agg = |f: &dyn Fn(&ErrorStats) -> f64| {
        let vals: Vec<f64> = approx.iter().map(|s| f(s)).collect();
        (
            vals.iter().cloned().fold(f64::INFINITY, f64::min),
            vals.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
            vals.iter().sum::<f64>() / n,
        )
    };
    let (er_min, er_max, er_avg) = agg(&|s| s.er_pct);
    let (mred_min, mred_max, mred_avg) = agg(&|s| s.mred_pct);
    let (nmed_min, nmed_max, nmed_avg) = agg(&|s| s.nmed_pct);
    TableISummary {
        er_min,
        er_max,
        er_avg,
        mred_min,
        mred_max,
        mred_avg,
        nmed_min,
        nmed_max,
        nmed_avg,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accurate_config_has_zero_error() {
        let s = exhaustive(Config::ACCURATE);
        assert_eq!(s.er_pct, 0.0);
        assert_eq!(s.mred_pct, 0.0);
        assert_eq!(s.nmed_pct, 0.0);
        assert_eq!(s.max_ed, 0);
    }

    #[test]
    fn min_config_stats_frozen() {
        // cfg 1 (mask 0): col1 full-OR + col2 pairwise-OR
        let s = exhaustive(Config::new(1).unwrap());
        assert!((s.er_pct - 9.375).abs() < 1e-9, "{}", s.er_pct);
        assert!((s.mred_pct - 0.04252).abs() < 1e-4, "{}", s.mred_pct);
    }

    #[test]
    fn max_config_stats_frozen() {
        let s = exhaustive(Config::MAX_APPROX);
        assert!((s.er_pct - 63.843).abs() < 0.01, "{}", s.er_pct);
        assert!((s.mred_pct - 2.9938).abs() < 0.01, "{}", s.mred_pct);
        assert!((s.nmed_pct - 0.4268).abs() < 0.001, "{}", s.nmed_pct);
    }

    #[test]
    fn table_i_shape_matches_paper() {
        let stats = full_table();
        let t = table_i(&stats);
        // paper Table I: ER 9.9609/61.8255/43.556, MRED 0.0548/3.684/2.125,
        // NMED 0.0028/0.3643/0.224.  Our scheme's locked values:
        assert!((t.er_min - 9.375).abs() < 0.01);
        assert!((t.er_max - 63.843).abs() < 0.05);
        assert!(t.er_avg > 40.0 && t.er_avg < 55.0);
        assert!((t.mred_min - 0.0425).abs() < 0.001);
        assert!((t.mred_max - 2.994).abs() < 0.01);
        assert!(t.nmed_avg > 0.15 && t.nmed_avg < 0.30);
    }

    #[test]
    fn mean_ed_consistent_with_nmed() {
        let s = exhaustive(Config::new(17).unwrap());
        let nmed_from_mean = s.mean_ed / (MAG_MAX * MAG_MAX) as f64 * 100.0;
        assert!((nmed_from_mean - s.nmed_pct).abs() < 1e-9);
    }
}

"""Tune the error-configurable approximate multiplier scheme.

The paper's multiplier is a 7x7 unsigned array multiplier (operands are
8-bit sign-magnitude; the sign is handled by an XOR outside the array)
with a 5-bit error-control input selecting one of 32 approximate
configurations, plus an accurate configuration 0.  The paper gives only
aggregate error statistics (Table I):

    ER    min  9.9609 %   max 61.8255 %   avg 43.556 %
    MRED  min  0.0548 %   max  3.6840 %   avg  2.125 %
    NMED  min  0.0028 %   max  0.3643 %   avg  0.224 %

This script searches a family of carry-disregarding column-OR schemes
(in the spirit of the paper's refs [14][16][17]) for parameters whose
exhaustive error statistics land closest to Table I, then emits the
frozen scheme so the Pallas kernel, the pure-jnp oracle, and the rust
bit-level model all implement the identical function.

Scheme family
-------------
The 13 partial-product columns (weights 2^0..2^12) are each either exact
(full adder tree, carries propagate) or approximated (column output =
OR of its partial products, carries disregarded).  A configuration
c in 1..32 maps to a 5-bit mask m = c-1; the scheme is defined by
  * base: set of columns approximated for every c >= 1
  * groups[g]: set of columns additionally approximated when bit g of m
    is set (g = 0..4)
Configuration 0 is exact.  Power saving comes from clock/operand gating
the adder cells of approximated columns, so higher columns save more.

Run:  python python/tools/tune_amul.py
"""

import itertools
import json
import sys

import numpy as np

N = 7  # magnitude bits
MAXV = (1 << N) - 1  # 127
NCOLS = 2 * N - 1  # 13 partial-product columns


def column_stats():
    """count_k and or_k for every (a, b) pair, exhaustively."""
    a = np.arange(128, dtype=np.int64)[:, None]
    b = np.arange(128, dtype=np.int64)[None, :]
    counts = []
    ors = []
    for k in range(NCOLS):
        cnt = np.zeros((128, 128), dtype=np.int64)
        orr = np.zeros((128, 128), dtype=np.int64)
        for i in range(N):
            j = k - i
            if 0 <= j < N:
                pp = ((a >> i) & 1) * ((b >> j) & 1)
                cnt += pp
                orr |= pp
        counts.append(cnt)
        ors.append(orr)
    return counts, ors


COUNTS, ORS = column_stats()
EXACT = np.arange(128, dtype=np.int64)[:, None] * np.arange(128, dtype=np.int64)[None, :]


def approx_product(approx_cols):
    """Product under the carry-disregarding column-OR approximation."""
    out = np.zeros((128, 128), dtype=np.int64)
    for k in range(NCOLS):
        col = ORS[k] if k in approx_cols else COUNTS[k]
        out += col << k
    return out


def metrics(approx_cols):
    p = approx_product(approx_cols)
    err = np.abs(p - EXACT)
    er = float(np.mean(err != 0) * 100.0)
    nz = EXACT != 0
    mred = float(np.mean(err[nz] / EXACT[nz]) * 100.0)
    nmed = float(np.mean(err) / (MAXV * MAXV) * 100.0)
    return er, mred, nmed


def eval_scheme(base, groups):
    """Stats over the 32 approximate configurations."""
    ers, mreds, nmeds = [], [], []
    for m in range(32):
        cols = set(base)
        for g in range(5):
            if (m >> g) & 1:
                cols |= set(groups[g])
        er, mred, nmed = metrics(cols)
        ers.append(er)
        mreds.append(mred)
        nmeds.append(nmed)
    return {
        "er": (min(ers), max(ers), float(np.mean(ers))),
        "mred": (min(mreds), max(mreds), float(np.mean(mreds))),
        "nmed": (min(nmeds), max(nmeds), float(np.mean(nmeds))),
        "per_cfg": list(zip(ers, mreds, nmeds)),
    }


TARGET = {
    "er": (9.9609, 61.8255, 43.556),
    "mred": (0.0548, 3.6840, 2.125),
    "nmed": (0.0028, 0.3643, 0.224),
}


def loss(stats):
    tot = 0.0
    for key in ("er", "mred", "nmed"):
        for got, want in zip(stats[key], TARGET[key]):
            # relative error in each aggregate; min values are tiny so use
            # log-space distance with a floor
            g = max(got, 1e-4)
            w = max(want, 1e-4)
            tot += (np.log(g) - np.log(w)) ** 2
    return tot


def main():
    # Single-column OR metrics, to guide the search
    print("single-column OR metrics (col: ER, MRED, NMED):")
    for k in range(8):
        er, mred, nmed = metrics({k})
        print(f"  col {k}: {er:7.3f}%  {mred:7.4f}%  {nmed:7.5f}%")

    # Candidate search: base is a prefix of low columns (possibly with a
    # single mid column), groups partition/step through higher columns.
    best = None
    # base candidates: contiguous low prefixes and small sets
    base_cands = []
    for hi in range(1, 5):
        base_cands.append(tuple(range(1, hi + 1)))  # col0 OR is exact, skip
    base_cands += [(1,), (2,), (1, 2), (1, 2, 3), (1, 3), (2, 3), (1, 2, 3, 4)]
    base_cands = sorted(set(base_cands))

    # group candidates: each bit g adds one column (increasing weight) so
    # that mask value correlates with error magnitude
    group_cands = []
    for cols in itertools.permutations(range(2, 9), 5):
        if list(cols) == sorted(cols):
            group_cands.append([{c} for c in cols])
    # also doubled variants: bit 4 gates two columns
    for cols in itertools.combinations(range(2, 9), 5):
        g = [{c} for c in cols[:4]]
        g.append({cols[4], cols[4] + 1} if cols[4] + 1 <= 8 else {cols[4]})
        group_cands.append(g)

    for base in base_cands:
        for groups in group_cands:
            stats = eval_scheme(base, groups)
            l = loss(stats)
            if best is None or l < best[0]:
                best = (l, base, groups, stats)

    l, base, groups, stats = best
    print(f"\nbest loss={l:.4f}")
    print(f"base={sorted(base)} groups={[sorted(g) for g in groups]}")
    for key in ("er", "mred", "nmed"):
        print(
            f"  {key:4s}: min {stats[key][0]:8.4f}  max {stats[key][1]:8.4f}  "
            f"avg {stats[key][2]:8.4f}   (paper {TARGET[key][0]} / "
            f"{TARGET[key][1]} / {TARGET[key][2]})"
        )
    out = {
        "n_bits": N,
        "base": sorted(base),
        "groups": [sorted(g) for g in groups],
        "stats": {k: stats[k] for k in ("er", "mred", "nmed")},
    }
    with open("/tmp/amul_scheme.json", "w") as f:
        json.dump(out, f, indent=2)
    print("wrote /tmp/amul_scheme.json")


if __name__ == "__main__":
    main()

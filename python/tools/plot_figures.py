"""Render publication-style PNGs of the paper's figures from the CSVs
written by `cargo run --release --example power_sweep`.

Usage:  python python/tools/plot_figures.py [--artifacts DIR] [--out DIR]
Outputs fig5.png, fig6.png, fig7.png, table1_er.png in --out.
"""

import argparse
import csv
import os

import matplotlib

matplotlib.use("Agg")
import matplotlib.pyplot as plt


def load_csv(path):
    with open(path) as f:
        return list(csv.DictReader(f))


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--artifacts", default="artifacts")
    ap.add_argument("--out", default="artifacts")
    args = ap.parse_args()

    sweep_path = os.path.join(args.artifacts, "power_sweep.csv")
    table1_path = os.path.join(args.artifacts, "table1.csv")
    if not os.path.exists(sweep_path):
        raise SystemExit(
            f"{sweep_path} missing — run `cargo run --release --example power_sweep`"
        )
    sweep = load_csv(sweep_path)
    cfgs = [int(r["cfg"]) for r in sweep]
    power = [float(r["total_mw"]) for r in sweep]
    saving = [float(r["network_saving_pct"]) for r in sweep]
    acc = [float(r["accuracy"]) * 100 for r in sweep]
    os.makedirs(args.out, exist_ok=True)

    # Fig. 5 — improvement per configuration
    fig, ax = plt.subplots(figsize=(9, 3.2))
    ax.bar(cfgs[1:], saving[1:], color="#2b6cb0")
    ax.axhline(13.33, ls="--", c="crimson", lw=1, label="paper max 13.33%")
    ax.set_xlabel("MAC configuration")
    ax.set_ylabel("overall power improvement [%]")
    ax.set_title("Fig. 5 — power improvement per configuration")
    ax.legend()
    fig.tight_layout()
    fig.savefig(os.path.join(args.out, "fig5.png"), dpi=150)

    # Fig. 6 — power + accuracy per configuration
    fig, ax1 = plt.subplots(figsize=(9, 3.6))
    ax1.plot(cfgs, power, "o-", c="#2b6cb0", label="power [mW]")
    ax1.axhline(5.55, ls=":", c="#2b6cb0", lw=1)
    ax1.axhline(4.81, ls=":", c="#2b6cb0", lw=1)
    ax1.set_xlabel("MAC configuration")
    ax1.set_ylabel("network power [mW]", color="#2b6cb0")
    ax2 = ax1.twinx()
    ax2.plot(cfgs, acc, "s--", c="#c05621", label="accuracy [%]")
    ax2.set_ylabel("test accuracy [%]", color="#c05621")
    ax1.set_title("Fig. 6 — power and accuracy per configuration")
    fig.tight_layout()
    fig.savefig(os.path.join(args.out, "fig6.png"), dpi=150)

    # Fig. 7 — trade-off scatter
    fig, ax = plt.subplots(figsize=(5.2, 4))
    ax.scatter(power[1:], acc[1:], c="#2b6cb0", label="approximate configs")
    ax.scatter(power[:1], acc[:1], c="crimson", marker="*", s=160, label="accurate")
    ax.set_xlabel("network power [mW]")
    ax.set_ylabel("test accuracy [%]")
    ax.set_title("Fig. 7 — accuracy vs power trade-off")
    ax.legend()
    fig.tight_layout()
    fig.savefig(os.path.join(args.out, "fig7.png"), dpi=150)

    # Table I visual — ER/MRED per config
    if os.path.exists(table1_path):
        t1 = load_csv(table1_path)
        c = [int(r["cfg"]) for r in t1]
        er = [float(r["er_pct"]) for r in t1]
        mred = [float(r["mred_pct"]) for r in t1]
        fig, ax1 = plt.subplots(figsize=(9, 3.2))
        ax1.bar(c[1:], er[1:], color="#4a5568", label="ER [%]")
        ax1.set_ylabel("ER [%]")
        ax1.set_xlabel("MAC configuration")
        ax2 = ax1.twinx()
        ax2.plot(c[1:], mred[1:], "o-", c="#c05621", label="MRED [%]")
        ax2.set_ylabel("MRED [%]", color="#c05621")
        ax1.set_title("Table I — multiplier error statistics per configuration")
        fig.tight_layout()
        fig.savefig(os.path.join(args.out, "table1_er.png"), dpi=150)

    print(f"wrote figures to {args.out}/")


if __name__ == "__main__":
    main()

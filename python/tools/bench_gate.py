"""CI bench-regression gate over `BENCH_forward.json`.

Compares a fresh `ecmac bench --forward --json` artifact against the
committed baseline at the repository root and fails (exit 1) when
throughput regressed by more than the tolerance (default 10%).

Two classes of check:

* **In-run invariants** (always enforced): within one artifact, the
  tiled-kernel path must not be slower than the in-process PR-4
  signed-gather baseline beyond tolerance, and the prefix-cached sweep
  must not be slower than the full-pass engine.  These are
  machine-matched (both sides measured in the same process seconds
  apart), so they are meaningful even on noisy shared CI runners.
* **Baseline comparison** (when the committed baseline holds real
  measurements): per-topology *relative* columns — `kernel_speedup`,
  `batch_speedup`, `sweep_speedup` — are compared fresh-vs-baseline.
  Ratios of two same-machine measurements transfer across machines;
  absolute img/s numbers do not, so they are only compared under
  `--absolute` (off in CI).

The committed baseline may be a pending stub (`"pending_measurement":
true`) on machines that cannot run the bench; the gate then skips the
baseline comparison, still enforces the in-run invariants, and prints
the refresh command.  Refresh with::

    cd rust && cargo run --release -- bench --forward --json fresh.json
    python3 ../python/tools/bench_gate.py fresh.json --write-baseline ../BENCH_forward.json

Override: maintainers can skip the gate on a PR by adding the
``bench-override`` label (the CI step is conditioned on it); use it for
changes that intentionally trade forward throughput for something else,
and refresh the baseline in the same PR.
"""

from __future__ import annotations

import argparse
import json
import shutil
import sys

# Relative (machine-transferable) columns compared against the baseline.
RATIO_COLUMNS = ("kernel_speedup", "batch_speedup", "sweep_speedup")
# Absolute columns, compared only under --absolute.
ABSOLUTE_COLUMNS = ("batch_per_sec", "batch_signed_per_sec", "per_image_per_sec")


def load(path):
    with open(path) as f:
        return json.load(f)


def rows_by_topology(doc):
    return {r["topology"]: r for r in doc.get("rows", [])}


def in_run_invariants(fresh, tolerance):
    """Same-process before/after invariants; returns a list of failures."""
    failures = []
    for topo, row in rows_by_topology(fresh).items():
        kernel = row.get("kernel_speedup")
        if kernel is not None and kernel < 1.0 - tolerance:
            failures.append(
                f"{topo}: tiled kernels are {kernel:.2f}x the PR-4 signed-gather "
                f"path (floor {1.0 - tolerance:.2f}x) — the rewrite regressed"
            )
        sweep = row.get("sweep_speedup")
        if sweep is not None and sweep < 1.0 - tolerance:
            failures.append(
                f"{topo}: prefix-cached sweep is {sweep:.2f}x the full-pass "
                f"engine (floor {1.0 - tolerance:.2f}x)"
            )
    return failures


def baseline_comparison(fresh, baseline, tolerance, absolute):
    """Fresh-vs-committed comparison; returns (failures, notes)."""
    failures, notes = [], []
    base_rows = rows_by_topology(baseline)
    fresh_rows = rows_by_topology(fresh)
    # shrinking coverage must not pass silently: a baseline topology
    # with no fresh measurement could hide an arbitrary regression
    for topo in base_rows:
        if topo not in fresh_rows:
            failures.append(
                f"{topo}: in the baseline but missing from the fresh artifact "
                f"— bench coverage shrank (refresh the baseline if intentional)"
            )
    columns = RATIO_COLUMNS + (ABSOLUTE_COLUMNS if absolute else ())
    for topo, row in fresh_rows.items():
        base = base_rows.get(topo)
        if base is None:
            notes.append(f"{topo}: not in the baseline — skipped")
            continue
        for col in columns:
            b, f = base.get(col), row.get(col)
            if b is None or f is None or b <= 0:
                continue
            drop = 1.0 - f / b
            if drop > tolerance:
                failures.append(
                    f"{topo}.{col}: {f:.2f} vs baseline {b:.2f} "
                    f"({drop * 100.0:.1f}% drop > {tolerance * 100.0:.0f}%)"
                )
            else:
                notes.append(f"{topo}.{col}: {f:.2f} vs baseline {b:.2f} ok")
    return failures, notes


def run(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("fresh", help="fresh BENCH_forward.json from this run")
    ap.add_argument(
        "--baseline",
        default=None,
        help="committed baseline (skipped when absent or pending)",
    )
    ap.add_argument(
        "--tolerance",
        type=float,
        default=0.10,
        help="allowed fractional throughput drop (default 0.10)",
    )
    ap.add_argument(
        "--absolute",
        action="store_true",
        help="also compare absolute img/s columns (same-machine baselines only)",
    )
    ap.add_argument(
        "--write-baseline",
        metavar="PATH",
        help="copy the fresh artifact over the baseline and exit",
    )
    args = ap.parse_args(argv)

    fresh = load(args.fresh)
    if fresh.get("bench") != "forward":
        print(f"error: {args.fresh} is not a forward bench artifact")
        return 2

    if args.write_baseline:
        shutil.copyfile(args.fresh, args.write_baseline)
        print(f"baseline refreshed: {args.write_baseline}")
        return 0

    failures = in_run_invariants(fresh, args.tolerance)

    if args.baseline:
        try:
            baseline = load(args.baseline)
        except FileNotFoundError:
            print(f"note: no baseline at {args.baseline}; in-run invariants only")
            baseline = None
        if baseline is not None and baseline.get("pending_measurement"):
            print(
                "note: committed baseline is a pending stub — refresh it with\n"
                "  cd rust && cargo run --release -- bench --forward --json fresh.json\n"
                "  python3 ../python/tools/bench_gate.py fresh.json "
                "--write-baseline ../BENCH_forward.json"
            )
        elif baseline is not None:
            more, notes = baseline_comparison(
                fresh, baseline, args.tolerance, args.absolute
            )
            failures.extend(more)
            for n in notes:
                print(n)

    if failures:
        print("\nbench-regression gate FAILED:")
        for f in failures:
            print(f"  - {f}")
        print(
            "\noverride: add the 'bench-override' label to the PR to skip this "
            "gate (and refresh the committed BENCH_forward.json baseline in the "
            "same PR if the trade-off is intentional)."
        )
        return 1
    print("bench-regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(run())

"""CI bench-regression gate over BENCH_forward.json / BENCH_serve.json.

Compares a fresh bench artifact (`ecmac bench --forward --json` or
`ecmac loadgen --json`) against the committed baseline at the
repository root and fails (exit 1) when throughput regressed by more
than the tolerance (default 10%).

Two classes of check, applied per artifact kind (the ``bench`` field):

* **In-run invariants** (always enforced): within one artifact, both
  sides of each comparison were measured in the same process seconds
  apart, so they are meaningful even on noisy shared CI runners.

  - ``forward``: the tiled-kernel path must not be slower than the
    in-process PR-4 signed-gather baseline beyond tolerance, and the
    prefix-cached sweep must not be slower than the full-pass engine.
    Rows carrying a ``pipeline_speedup`` (the ``ecmac bench --pipeline``
    artifact, same ``forward`` kind) additionally require the
    stage-pipelined executor to beat the row-partitioned path within
    tolerance on topologies where the planner engaged; rows flagged
    ``pipeline_fallback`` (shallow topology or too few cores — the
    planner declined and both sides ran the same code) are exempt.
    CI gates the ``BENCH_pipeline.json`` artifact on these in-run
    invariants only (no ``--baseline``), since its topology set differs
    from the committed forward baseline's.
  - ``serve``: per governor policy, the adaptive batching window must
    not serve less throughput than the pinned batch=1 front-end at the
    same offered load (``adaptive_speedup >= 1 - tolerance``), and the
    run must actually have answered requests.
  - ``analyze``: the static-verification artifact (``ecmac analyze
    --json``).  Not a throughput bench: the gate requires every check —
    top-level range checks and the nested per-plan liveness checks —
    to be ``proved`` (zero refuted **and** zero unknown; an undecided
    analysis fails the gate), per-row and grand summaries to tally
    consistently, and the row set to be non-empty.  There is no baseline
    to compare against.
  - ``chaos``: the fault-injection campaign artifact (``ecmac chaos
    --json``).  Containment is pass/fail: every injected fault class
    must end ``masked``, ``detected_degraded``, or ``failed_fast`` —
    never ``silent`` (corrupt output served as good) or ``hung`` (a
    reply that never resolved) — with zero unresolved replies per
    class, a non-empty class set, and a summary that tallies with the
    classes.  There is no baseline to compare against.
  - ``sentinel``: the accuracy-audit campaign artifact (``ecmac
    sentinel --json``).  Detection-and-recovery is pass/fail: every
    audit class must end ``clean`` or ``detected_recovered`` — never
    ``unrecovered``, ``silent``, or ``hung`` — with zero unresolved
    replies per class; classes carrying an online-vs-offline
    ``estimate`` cross-check must land within their tolerance.  There
    is no baseline to compare against.

* **Baseline comparison** (when the committed baseline holds real
  measurements): relative columns — ``kernel_speedup`` /
  ``batch_speedup`` / ``sweep_speedup`` per topology for ``forward``,
  ``adaptive_speedup`` per policy for ``serve`` — are compared
  fresh-vs-baseline.  Ratios of two same-machine measurements transfer
  across machines; absolute img/s or req/s numbers do not, so they are
  only compared under ``--absolute`` (off in CI).

The committed baseline may be a pending stub (`"pending_measurement":
true`) on machines that cannot run the bench; the gate then skips the
baseline comparison, still enforces the in-run invariants, and prints
the refresh command.  Refresh with::

    cd rust && cargo run --release -- bench --forward --json fresh.json
    python3 ../python/tools/bench_gate.py fresh.json --write-baseline ../BENCH_forward.json

    cd rust && cargo run --release -- loadgen --synthetic --json fresh_serve.json
    python3 ../python/tools/bench_gate.py fresh_serve.json --write-baseline ../BENCH_serve.json

Override: maintainers can skip the gate on a PR by adding the
``bench-override`` label (the CI step is conditioned on it); use it for
changes that intentionally trade throughput for something else, and
refresh the matching baseline in the same PR.
"""

from __future__ import annotations

import argparse
import json
import shutil
import sys

# Relative (machine-transferable) columns compared against the baseline.
RATIO_COLUMNS = ("kernel_speedup", "batch_speedup", "sweep_speedup", "pipeline_speedup")
# Absolute columns, compared only under --absolute.
ABSOLUTE_COLUMNS = (
    "batch_per_sec",
    "batch_signed_per_sec",
    "per_image_per_sec",
    "pipeline_per_sec",
    "batch_par_per_sec",
)

SERVE_RATIO_COLUMNS = ("adaptive_speedup",)
SERVE_ABSOLUTE_COLUMNS = ("throughput_rps", "batch1_throughput_rps")


def load(path):
    with open(path) as f:
        return json.load(f)


def rows_by_key(doc, key):
    return {r[key]: r for r in doc.get("rows", [])}


def in_run_invariants(fresh, tolerance):
    """Forward-bench same-process invariants; returns a list of failures."""
    failures = []
    for topo, row in rows_by_key(fresh, "topology").items():
        kernel = row.get("kernel_speedup")
        if kernel is not None and kernel < 1.0 - tolerance:
            failures.append(
                f"{topo}: tiled kernels are {kernel:.2f}x the PR-4 signed-gather "
                f"path (floor {1.0 - tolerance:.2f}x) — the rewrite regressed"
            )
        sweep = row.get("sweep_speedup")
        if sweep is not None and sweep < 1.0 - tolerance:
            failures.append(
                f"{topo}: prefix-cached sweep is {sweep:.2f}x the full-pass "
                f"engine (floor {1.0 - tolerance:.2f}x)"
            )
        pipeline = row.get("pipeline_speedup")
        if (
            pipeline is not None
            and not row.get("pipeline_fallback")
            and pipeline < 1.0 - tolerance
        ):
            failures.append(
                f"{topo}: stage-pipelined executor is {pipeline:.2f}x the "
                f"row-partitioned path (floor {1.0 - tolerance:.2f}x) on a "
                f"topology where the planner engaged — pipelining lost"
            )
    return failures


def serve_in_run_invariants(fresh, tolerance):
    """Serve-bench same-process invariants; returns a list of failures.

    Both front-ends in a row faced the same offered load from the same
    generator seconds apart, so adaptive-vs-batch=1 is machine-matched.
    """
    failures = []
    rows = rows_by_key(fresh, "policy")
    if not rows:
        failures.append("serve artifact has no rows — the loadgen run produced nothing")
    for policy, row in rows.items():
        speedup = row.get("adaptive_speedup")
        if speedup is not None and speedup < 1.0 - tolerance:
            failures.append(
                f"{policy}: adaptive batching is {speedup:.2f}x the batch=1 "
                f"front-end at equal offered load (floor {1.0 - tolerance:.2f}x) "
                f"— the adaptive window lost throughput"
            )
        answered = row.get("answered")
        if answered is not None and answered <= 0:
            failures.append(
                f"{policy}: zero requests answered — the serve path is broken, "
                f"not merely slow"
            )
    return failures


def _tally(checks):
    """Count check verdicts -> (proved, refuted, unknown)."""
    verdicts = [c.get("verdict") for c in checks]
    return (
        verdicts.count("proved"),
        verdicts.count("refuted"),
        verdicts.count("unknown"),
    )


def analyze_invariants(fresh, tolerance):
    """Static-verification invariants: every check proved, zero unknown.

    ``tolerance`` is accepted for interface uniformity but unused —
    a proof either holds or it does not.
    """
    del tolerance
    failures = []
    rows = fresh.get("rows", [])
    if not rows:
        failures.append("analyze artifact has no rows — the analyzer verified nothing")
    for row in rows:
        rid = row.get("id", "<unnamed>")
        checks = list(row.get("checks", []))
        for plan in row.get("plans", []):
            checks.extend(plan.get("checks", []))
        for c in checks:
            verdict = c.get("verdict")
            if verdict != "proved":
                failures.append(
                    f"{rid}: {c.get('name')} is {verdict!r} — {c.get('detail')}"
                )
        proved, refuted, unknown = _tally(checks)
        summary = row.get("summary", {})
        if (
            summary.get("proved") != proved
            or summary.get("refuted") != refuted
            or summary.get("unknown") != unknown
        ):
            failures.append(
                f"{rid}: summary {summary} does not tally with its checks "
                f"({proved} proved, {refuted} refuted, {unknown} unknown)"
            )
    grand = fresh.get("summary", {})
    if grand.get("refuted", 0) != 0 or grand.get("unknown", 0) != 0:
        failures.append(
            f"grand summary reports {grand.get('refuted', 0)} refuted / "
            f"{grand.get('unknown', 0)} unknown checks"
        )
    return failures


CHAOS_GOOD_OUTCOMES = ("masked", "detected_degraded", "failed_fast")
CHAOS_BAD_OUTCOMES = ("silent", "hung")


def chaos_invariants(fresh, tolerance):
    """Fault-campaign invariants: every class contained, every reply resolved.

    ``tolerance`` is accepted for interface uniformity but unused —
    a fault is contained or it is not.
    """
    del tolerance
    failures = []
    classes = fresh.get("classes", [])
    if not classes:
        failures.append("chaos artifact has no classes — the campaign injected nothing")
    tally = dict.fromkeys(CHAOS_GOOD_OUTCOMES + CHAOS_BAD_OUTCOMES, 0)
    for c in classes:
        name = c.get("class", "<unnamed>")
        outcome = c.get("outcome")
        if outcome not in tally:
            failures.append(f"{name}: unknown outcome {outcome!r} — {c.get('detail')}")
        else:
            tally[outcome] += 1
            if outcome in CHAOS_BAD_OUTCOMES:
                failures.append(f"{name}: ended {outcome} — {c.get('detail')}")
        unresolved = c.get("unresolved", 0)
        if unresolved:
            failures.append(
                f"{name}: {unresolved} replies never resolved — the stack can "
                f"leave callers hanging under this fault"
            )
    summary = fresh.get("summary", {})
    for outcome, count in tally.items():
        if summary.get(outcome) != count:
            failures.append(
                f"summary[{outcome}] = {summary.get(outcome)!r} does not tally "
                f"with the classes ({count})"
            )
    if summary.get("total") != len(classes):
        failures.append(
            f"summary total {summary.get('total')!r} != {len(classes)} classes"
        )
    return failures


SENTINEL_GOOD_OUTCOMES = ("clean", "detected_recovered")
SENTINEL_BAD_OUTCOMES = ("unrecovered", "silent", "hung")


def sentinel_invariants(fresh, tolerance):
    """Accuracy-audit invariants: every class detected-and-recovered or
    clean, every reply resolved, every carried estimate within tolerance.

    ``tolerance`` is accepted for interface uniformity but unused — each
    estimate cross-check travels with its own tolerance, pinned by the
    campaign when the offline reference was measured.
    """
    del tolerance
    failures = []
    classes = fresh.get("classes", [])
    if not classes:
        failures.append("sentinel artifact has no classes — the campaign audited nothing")
    tally = dict.fromkeys(SENTINEL_GOOD_OUTCOMES + SENTINEL_BAD_OUTCOMES, 0)
    for c in classes:
        name = c.get("class", "<unnamed>")
        outcome = c.get("outcome")
        if outcome not in tally:
            failures.append(f"{name}: unknown outcome {outcome!r} — {c.get('detail')}")
        else:
            tally[outcome] += 1
            if outcome in SENTINEL_BAD_OUTCOMES:
                failures.append(f"{name}: ended {outcome} — {c.get('detail')}")
        unresolved = c.get("unresolved", 0)
        if unresolved:
            failures.append(
                f"{name}: {unresolved} replies never resolved — the audit "
                f"machinery can leave callers hanging"
            )
        estimate = c.get("estimate")
        if estimate is not None:
            observed = estimate.get("observed")
            predicted = estimate.get("predicted")
            allowed = estimate.get("tolerance")
            if observed is None or predicted is None or allowed is None:
                failures.append(
                    f"{name}: estimate cross-check is missing a field ({estimate})"
                )
            elif abs(observed - predicted) > allowed:
                failures.append(
                    f"{name}: online disagreement estimate {observed:.4f} is "
                    f"off the offline prediction {predicted:.4f} by more than "
                    f"{allowed:.4f} — the shadow audit is miscalibrated"
                )
    summary = fresh.get("summary", {})
    for outcome, count in tally.items():
        if summary.get(outcome) != count:
            failures.append(
                f"summary[{outcome}] = {summary.get(outcome)!r} does not tally "
                f"with the classes ({count})"
            )
    if summary.get("total") != len(classes):
        failures.append(
            f"summary total {summary.get('total')!r} != {len(classes)} classes"
        )
    return failures


# Per-artifact-kind gate configuration, selected by the "bench" field.
KINDS = {
    "forward": {
        "key": "topology",
        "ratio_columns": RATIO_COLUMNS,
        "absolute_columns": ABSOLUTE_COLUMNS,
        "invariants": in_run_invariants,
        "refresh": (
            "  cd rust && cargo run --release -- bench --forward --json fresh.json\n"
            "  python3 ../python/tools/bench_gate.py fresh.json "
            "--write-baseline ../BENCH_forward.json"
        ),
    },
    "serve": {
        "key": "policy",
        "ratio_columns": SERVE_RATIO_COLUMNS,
        "absolute_columns": SERVE_ABSOLUTE_COLUMNS,
        "invariants": serve_in_run_invariants,
        "refresh": (
            "  cd rust && cargo run --release -- loadgen --synthetic "
            "--json fresh_serve.json\n"
            "  python3 ../python/tools/bench_gate.py fresh_serve.json "
            "--write-baseline ../BENCH_serve.json"
        ),
    },
    "analyze": {
        "key": "id",
        # proofs are pass/fail, not throughput: nothing to ratio-compare
        "ratio_columns": (),
        "absolute_columns": (),
        "invariants": analyze_invariants,
        "refresh": (
            "  cd rust && cargo run --release -- analyze --json ANALYZE.json"
        ),
    },
    "chaos": {
        "key": "class",
        # containment is pass/fail, not throughput: nothing to ratio-compare
        "ratio_columns": (),
        "absolute_columns": (),
        "invariants": chaos_invariants,
        "refresh": (
            "  cd rust && cargo run --release -- chaos --json CHAOS.json"
        ),
    },
    "sentinel": {
        "key": "class",
        # detection-and-recovery is pass/fail, not throughput
        "ratio_columns": (),
        "absolute_columns": (),
        "invariants": sentinel_invariants,
        "refresh": (
            "  cd rust && cargo run --release -- sentinel --json SENTINEL.json"
        ),
    },
}


def baseline_comparison(fresh, baseline, tolerance, absolute, kind):
    """Fresh-vs-committed comparison; returns (failures, notes)."""
    failures, notes = [], []
    key = kind["key"]
    base_rows = rows_by_key(baseline, key)
    fresh_rows = rows_by_key(fresh, key)
    # shrinking coverage must not pass silently: a baseline row with no
    # fresh measurement could hide an arbitrary regression
    for name in base_rows:
        if name not in fresh_rows:
            failures.append(
                f"{name}: in the baseline but missing from the fresh artifact "
                f"— bench coverage shrank (refresh the baseline if intentional)"
            )
    columns = kind["ratio_columns"] + (kind["absolute_columns"] if absolute else ())
    for name, row in fresh_rows.items():
        base = base_rows.get(name)
        if base is None:
            notes.append(f"{name}: not in the baseline — skipped")
            continue
        for col in columns:
            b, f = base.get(col), row.get(col)
            if b is None or f is None or b <= 0:
                continue
            drop = 1.0 - f / b
            if drop > tolerance:
                failures.append(
                    f"{name}.{col}: {f:.2f} vs baseline {b:.2f} "
                    f"({drop * 100.0:.1f}% drop > {tolerance * 100.0:.0f}%)"
                )
            else:
                notes.append(f"{name}.{col}: {f:.2f} vs baseline {b:.2f} ok")
    return failures, notes


def run(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("fresh", help="fresh bench artifact from this run")
    ap.add_argument(
        "--baseline",
        default=None,
        help="committed baseline (skipped when absent or pending)",
    )
    ap.add_argument(
        "--tolerance",
        type=float,
        default=0.10,
        help="allowed fractional throughput drop (default 0.10)",
    )
    ap.add_argument(
        "--absolute",
        action="store_true",
        help="also compare absolute throughput columns (same-machine baselines only)",
    )
    ap.add_argument(
        "--write-baseline",
        metavar="PATH",
        help="copy the fresh artifact over the baseline and exit",
    )
    args = ap.parse_args(argv)

    fresh = load(args.fresh)
    kind = KINDS.get(fresh.get("bench"))
    if kind is None:
        print(
            f"error: {args.fresh} is not a recognised bench artifact "
            f"(bench={fresh.get('bench')!r}, expected one of {sorted(KINDS)})"
        )
        return 2

    if args.write_baseline:
        shutil.copyfile(args.fresh, args.write_baseline)
        print(f"baseline refreshed: {args.write_baseline}")
        return 0

    failures = kind["invariants"](fresh, args.tolerance)

    if args.baseline:
        try:
            baseline = load(args.baseline)
        except FileNotFoundError:
            print(f"note: no baseline at {args.baseline}; in-run invariants only")
            baseline = None
        if baseline is not None and baseline.get("bench") != fresh.get("bench"):
            failures.append(
                f"baseline {args.baseline} is a {baseline.get('bench')!r} "
                f"artifact, fresh is {fresh.get('bench')!r} — wrong baseline "
                f"wired up"
            )
        elif baseline is not None and baseline.get("pending_measurement"):
            print(
                "note: committed baseline is a pending stub — refresh it with\n"
                + kind["refresh"]
            )
        elif baseline is not None:
            more, notes = baseline_comparison(
                fresh, baseline, args.tolerance, args.absolute, kind
            )
            failures.extend(more)
            for n in notes:
                print(n)

    if failures:
        print("\nbench-regression gate FAILED:")
        for f in failures:
            print(f"  - {f}")
        print(
            "\noverride: add the 'bench-override' label to the PR to skip this "
            "gate (and refresh the committed baseline at the repo root in the "
            "same PR if the trade-off is intentional)."
        )
        return 1
    print("bench-regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(run())

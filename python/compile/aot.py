"""AOT compile path: dataset -> training -> quantization -> HLO artifacts.

Python runs exactly once (``make artifacts``); the rust coordinator is
self-contained afterwards.  Interchange format is HLO *text*, not a
serialized ``HloModuleProto``: jax >= 0.5 emits protos with 64-bit
instruction ids which the xla crate's bundled XLA (xla_extension 0.5.1)
rejects; the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Artifacts written to ``--outdir`` (default ../artifacts):

  train-images.idx3 / train-labels.idx1      synthetic dataset (idx format)
  test-images.idx3  / test-labels.idx1
  feature-indices.txt                        the frozen 784 -> 62 wiring
  weights_f32.json                           trained float parameters + history
  weights_q.json                             sign-magnitude encoded parameters
  model_approx_b{1,16,128}.hlo.txt           quantized approx fwd (Pallas inside)
  model_ref_f32_b128.hlo.txt                 float reference fwd
  golden_mul.json                            multiplier golden vectors (rust parity)
  golden_mlp.json                            end-to-end MLP golden vectors
  amul_metrics.json                          exhaustive ER/MRED/NMED per config
  accuracy_sweep.json                        test accuracy for all 33 configs
  schedule_sweep.json                        per-layer sensitivity sweep (versioned;
                                             same schema as `ecmac sweep --per-layer`)
  manifest.json                              index of everything above

Usage:  cd python && python -m compile.aot --outdir ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import dataset as ds
from . import model
from . import train as train_mod
from .kernels import amul_spec as spec
from .kernels import ref

HLO_BATCH_SIZES = (1, 16, 128)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_approx_hlo(outdir: str, batch: int) -> str:
    """Lower the quantized approximate forward pass for one batch size."""

    def fwd(x, w1, b1, w2, b2, cfg):
        logits, hidden = model.forward_q_pallas(x, w1, b1, w2, b2, cfg[0])
        return logits, hidden

    i32 = jnp.int32
    args = (
        jax.ShapeDtypeStruct((batch, model.N_INPUTS), i32),
        jax.ShapeDtypeStruct((model.N_INPUTS, model.N_HIDDEN), i32),
        jax.ShapeDtypeStruct((model.N_HIDDEN,), i32),
        jax.ShapeDtypeStruct((model.N_HIDDEN, model.N_OUTPUTS), i32),
        jax.ShapeDtypeStruct((model.N_OUTPUTS,), i32),
        jax.ShapeDtypeStruct((1,), i32),
    )
    text = to_hlo_text(jax.jit(fwd).lower(*args))
    name = f"model_approx_b{batch}.hlo.txt"
    with open(os.path.join(outdir, name), "w") as f:
        f.write(text)
    return name


def export_ref_hlo(outdir: str, batch: int = 128) -> str:
    """Lower the float reference forward pass."""

    def fwd(x, w1, b1, w2, b2):
        h = jnp.clip(x @ w1 + b1, 0.0, model.ACT_MAX)
        return (h @ w2 + b2,)

    f32 = jnp.float32
    args = (
        jax.ShapeDtypeStruct((batch, model.N_INPUTS), f32),
        jax.ShapeDtypeStruct((model.N_INPUTS, model.N_HIDDEN), f32),
        jax.ShapeDtypeStruct((model.N_HIDDEN,), f32),
        jax.ShapeDtypeStruct((model.N_HIDDEN, model.N_OUTPUTS), f32),
        jax.ShapeDtypeStruct((model.N_OUTPUTS,), f32),
    )
    text = to_hlo_text(jax.jit(fwd).lower(*args))
    name = f"model_ref_f32_b{batch}.hlo.txt"
    with open(os.path.join(outdir, name), "w") as f:
        f.write(text)
    return name


def golden_multiplier_vectors(n_per_cfg: int = 256, seed: int = 7):
    """Random (a, b, cfg, product) vectors from the scalar golden model."""
    rng = np.random.default_rng(seed)
    out = []
    for cfg in range(spec.N_CONFIGS):
        a = rng.integers(0, 256, n_per_cfg)
        b = rng.integers(0, 256, n_per_cfg)
        prods = [
            spec.mul8_sm_approx(int(x), int(w), cfg) for x, w in zip(a, b)
        ]
        out.append(
            {
                "cfg": cfg,
                "a": a.tolist(),
                "b": b.tolist(),
                "product": prods,
                "levels": spec.column_levels(cfg),
            }
        )
    return out


def golden_mlp_vectors(params_q, x_enc, labels, cfgs=(0, 1, 16, 32)):
    """End-to-end integer pipeline vectors for the rust datapath simulator."""
    vec = {"x": np.asarray(x_enc).tolist(), "labels": np.asarray(labels).tolist()}
    cases = []
    for cfg in cfgs:
        logits, hidden = model.forward_q_ref(params_q, x_enc, cfg)
        cases.append(
            {
                "cfg": int(cfg),
                "logits": np.asarray(logits).tolist(),
                "hidden": np.asarray(hidden).tolist(),
                "pred": model.predict_q(logits).tolist(),
            }
        )
    vec["cases"] = cases
    return vec


def amul_metric_table():
    """Exhaustive ER/MRED/NMED for every configuration (Table I input)."""
    rows = []
    for cfg in range(spec.N_CONFIGS):
        er, mred, nmed = spec.exhaustive_metrics(cfg)
        rows.append(
            {
                "cfg": cfg,
                "er_pct": er,
                "mred_pct": mred,
                "nmed_pct": nmed,
                "levels": spec.column_levels(cfg),
            }
        )
    return rows


SCHEDULE_SWEEP_SCHEMA = "ecmac-schedule-sweep"
SCHEDULE_SWEEP_SCHEMA_VERSION = 1


def _batched_accuracy(fwd, x_enc, labels, batch, *cfgs):
    """Accuracy of a jitted argmax forward over the set, in batches.

    ``fwd(xb, *cfgs)`` must return predicted labels; the shared scaffold
    behind both the uniform and the per-layer sweeps.
    """
    n = len(x_enc)
    correct = 0
    for lo in range(0, n, batch):
        pred = np.asarray(fwd(x_enc[lo : lo + batch], *(jnp.int32(c) for c in cfgs)))
        correct += int(np.sum(pred == labels[lo : lo + batch]))
    return correct / n


def schedule_sweep(params_q, x_enc, labels, batch: int = 4096, baseline=None):
    """Per-layer sensitivity sweep: test accuracy with one layer
    approximated at a time (the other layer accurate), emitted in the
    same versioned schema the native harness writes (``ecmac sweep
    --per-layer`` -> ``schedule_sweep.json``).  The rust
    ``SensitivityModel`` loads either producer's file.

    ``baseline`` skips re-measuring the all-accurate accuracy when the
    caller already has it (``accuracy_sweep``'s cfg-0 row is measured
    through the identical forward pass).
    """

    @jax.jit
    def fwd(xb, cfg_l0, cfg_l1):
        logits, _ = ref.mlp_forward_q_sched(
            xb,
            params_q["w1"],
            params_q["b1"],
            params_q["w2"],
            params_q["b2"],
            cfg_l0,
            cfg_l1,
        )
        return jnp.argmax(logits, axis=-1)

    n = len(x_enc)
    x_enc = jnp.asarray(x_enc, dtype=jnp.int32)
    labels = np.asarray(labels)
    if baseline is None:
        baseline = _batched_accuracy(fwd, x_enc, labels, batch, 0, 0)
    layers = []
    for layer in range(2):
        drop = [0.0]
        for cfg in range(1, spec.N_CONFIGS):
            cfgs = (cfg, 0) if layer == 0 else (0, cfg)
            acc = _batched_accuracy(fwd, x_enc, labels, batch, *cfgs)
            drop.append(baseline - acc)
        layers.append({"layer": layer, "drop": drop})
    return {
        "schema": SCHEDULE_SWEEP_SCHEMA,
        "schema_version": SCHEDULE_SWEEP_SCHEMA_VERSION,
        "topology": [model.N_INPUTS, model.N_HIDDEN, model.N_OUTPUTS],
        "images": n,
        "baseline_accuracy": baseline,
        "layers": layers,
    }


def accuracy_sweep(params_q, x_enc, labels, batch: int = 4096):
    """Quantized test accuracy for all 33 configurations (jitted)."""

    @jax.jit
    def fwd(xb, cfg):
        logits, _ = model.forward_q_ref(params_q, xb, cfg)
        return jnp.argmax(logits, axis=-1)

    x_enc = jnp.asarray(x_enc, dtype=jnp.int32)
    labels = np.asarray(labels)
    return [
        {"cfg": cfg, "accuracy": _batched_accuracy(fwd, x_enc, labels, batch, cfg)}
        for cfg in range(spec.N_CONFIGS)
    ]


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--out", default=None, help="legacy single-file alias; ignored")
    ap.add_argument("--epochs", type=int, default=25)
    ap.add_argument("--n-train", type=int, default=60000)
    ap.add_argument("--n-test", type=int, default=10000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--skip-sweep", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    outdir = args.outdir
    os.makedirs(outdir, exist_ok=True)

    print("[aot] dataset ...")
    tr_i, tr_l, te_i, te_l, feat = ds.build_cached(
        outdir, args.n_train, args.n_test, force=args.force
    )

    wpath = os.path.join(outdir, "weights_f32.json")
    if os.path.exists(wpath) and not args.force:
        print("[aot] reusing trained weights")
        with open(wpath) as f:
            saved = json.load(f)
        params = {
            k: jnp.asarray(np.array(saved[k], dtype=np.float32))
            for k in ("w1", "b1", "w2", "b2")
        }
        history = saved.get("history", [])
    else:
        print("[aot] training ...")
        x_train, _ = train_mod.features_from_images(tr_i, feat)
        x_test, _ = train_mod.features_from_images(te_i, feat)
        params, history = train_mod.train(
            x_train,
            tr_l.astype(np.int32),
            x_test,
            te_l.astype(np.int32),
            epochs=args.epochs,
            seed=args.seed,
        )
        with open(wpath, "w") as f:
            json.dump(
                {
                    "w1": np.asarray(params["w1"]).tolist(),
                    "b1": np.asarray(params["b1"]).tolist(),
                    "w2": np.asarray(params["w2"]).tolist(),
                    "b2": np.asarray(params["b2"]).tolist(),
                    "history": history,
                },
                f,
            )

    params_q = model.quantize_params(params)
    _, test_mags = train_mod.features_from_images(te_i, feat)

    print("[aot] quantized weights ...")
    with open(os.path.join(outdir, "weights_q.json"), "w") as f:
        json.dump(
            {
                "format": "sign-magnitude-8bit",
                "scale": 128,
                "n_inputs": model.N_INPUTS,
                "n_hidden": model.N_HIDDEN,
                "n_outputs": model.N_OUTPUTS,
                "w1": params_q["w1"].tolist(),
                "b1": params_q["b1"].tolist(),
                "w2": params_q["w2"].tolist(),
                "b2": params_q["b2"].tolist(),
                "feature_indices": feat.tolist(),
            },
            f,
        )

    print("[aot] HLO exports ...")
    hlo_files = [export_approx_hlo(outdir, b) for b in HLO_BATCH_SIZES]
    hlo_files.append(export_ref_hlo(outdir))

    print("[aot] golden vectors ...")
    with open(os.path.join(outdir, "golden_mul.json"), "w") as f:
        json.dump(golden_multiplier_vectors(), f)
    with open(os.path.join(outdir, "golden_mlp.json"), "w") as f:
        json.dump(
            golden_mlp_vectors(params_q, test_mags[:32], te_l[:32]), f
        )

    print("[aot] multiplier metric table ...")
    with open(os.path.join(outdir, "amul_metrics.json"), "w") as f:
        json.dump(amul_metric_table(), f, indent=1)

    if not args.skip_sweep:
        print("[aot] accuracy sweep over 33 configs ...")
        sweep = accuracy_sweep(params_q, test_mags, te_l)
        with open(os.path.join(outdir, "accuracy_sweep.json"), "w") as f:
            json.dump(sweep, f, indent=1)
        acc0 = sweep[0]["accuracy"]
        worst = min(s["accuracy"] for s in sweep[1:])
        print(
            f"[aot] accurate acc {acc0 * 100:.2f}%  worst approx {worst * 100:.2f}%"
            f"  (paper: 89.67% / 88.75%)"
        )
        print("[aot] per-layer schedule sweep ...")
        sched_sweep = schedule_sweep(params_q, test_mags, te_l, baseline=acc0)
        with open(os.path.join(outdir, "schedule_sweep.json"), "w") as f:
            json.dump(sched_sweep, f, indent=1)
        worst_l0 = max(sched_sweep["layers"][0]["drop"])
        worst_l1 = max(sched_sweep["layers"][1]["drop"])
        print(
            f"[aot] per-layer worst drop: hidden {worst_l0 * 100:.2f}pp"
            f"  output {worst_l1 * 100:.2f}pp"
        )

    manifest = {
        "network": {
            "inputs": model.N_INPUTS,
            "hidden": model.N_HIDDEN,
            "outputs": model.N_OUTPUTS,
            "physical_neurons": 10,
            "configs": spec.N_CONFIGS,
        },
        "hlo": {
            "approx": {str(b): f"model_approx_b{b}.hlo.txt" for b in HLO_BATCH_SIZES},
            "ref_f32": "model_ref_f32_b128.hlo.txt",
            "param_order_approx": ["x", "w1", "b1", "w2", "b2", "cfg"],
            "param_order_ref": ["x", "w1", "b1", "w2", "b2"],
            "outputs_approx": ["logits", "hidden"],
        },
        "dataset": {
            "train_images": "train-images.idx3",
            "train_labels": "train-labels.idx1",
            "test_images": "test-images.idx3",
            "test_labels": "test-labels.idx1",
            "feature_indices": "feature-indices.txt",
            "n_train": int(len(tr_i)),
            "n_test": int(len(te_i)),
        },
        "weights": {"float": "weights_f32.json", "quantized": "weights_q.json"},
        "golden": {"mul": "golden_mul.json", "mlp": "golden_mlp.json"},
        "metrics": {
            "amul": "amul_metrics.json",
            "accuracy_sweep": "accuracy_sweep.json",
            "schedule_sweep": "schedule_sweep.json",
        },
    }
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print("[aot] done.")


if __name__ == "__main__":
    main()

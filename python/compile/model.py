"""Layer-2 JAX model: the paper's 62-30-10 MLP, float and quantized.

The float model is the training-time surrogate: it mirrors the hardware
pipeline's clipped-ReLU (the 8-bit saturation stage clamps hidden
activations at 127/128) so post-training quantization to the sign-
magnitude fixed-point format loses little accuracy.

The quantized model is the bit-exact integer pipeline; its matmuls run
through the Layer-1 Pallas kernel (``kernels.approx_mul``) so the whole
forward pass — including the error-configurable multiplier — lowers into
a single HLO module for the rust runtime.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref
from .kernels.approx_mul import approx_matmul_pallas

N_INPUTS = 62
N_HIDDEN = 30
N_OUTPUTS = 10

# hardware activation ceiling: saturation clamps at 127 / 128
ACT_MAX = 127.0 / 128.0
# weights/biases must encode into 8-bit sign-magnitude at scale 1/128
W_MAX = 127.0 / 128.0


def init_params(seed: int = 0):
    """He-style init, scaled conservatively for the clipped range."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    w1 = jax.random.normal(k1, (N_INPUTS, N_HIDDEN)) * np.sqrt(2.0 / N_INPUTS) * 0.5
    w2 = jax.random.normal(k2, (N_HIDDEN, N_OUTPUTS)) * np.sqrt(2.0 / N_HIDDEN) * 0.5
    return {
        "w1": w1.astype(jnp.float32),
        "b1": jnp.zeros((N_HIDDEN,), jnp.float32),
        "w2": w2.astype(jnp.float32),
        "b2": jnp.zeros((N_OUTPUTS,), jnp.float32),
    }


def clip_params(params):
    """Project parameters into the representable sign-magnitude range."""
    return {k: jnp.clip(v, -W_MAX, W_MAX) for k, v in params.items()}


def forward_f32(params, x):
    """Hardware-aware float forward: clipped ReLU at the saturation level.

    ``x``: (B, 62) float in [0, 1).  Returns logits (B, 10).
    """
    h = jnp.clip(x @ params["w1"] + params["b1"], 0.0, ACT_MAX)
    return h @ params["w2"] + params["b2"]


def quantize_params(params):
    """Float params -> sign-magnitude int32 encodings (scale 1/128)."""

    def q(v):
        s = np.clip(np.round(np.asarray(v) * 128.0), -127, 127).astype(np.int32)
        return np.where(s < 0, 0x80 | (-s), s).astype(np.int32)

    return {
        "w1": q(params["w1"]),
        "b1": q(params["b1"]),
        "w2": q(params["w2"]),
        "b2": q(params["b2"]),
    }


def forward_q_ref(params_q, x_enc, cfg):
    """Quantized forward via the pure-jnp oracle (testing)."""
    return ref.mlp_forward_q(
        x_enc, params_q["w1"], params_q["b1"], params_q["w2"], params_q["b2"], cfg
    )


def forward_q_pallas(x_enc, w1, b1, w2, b2, cfg):
    """Quantized forward via the Pallas kernel — the AOT entry point.

    Flat-argument signature (no dicts) so ``jax.jit(...).lower()``
    produces an HLO module with a stable parameter order for the rust
    runtime: (x, w1, b1, w2, b2, cfg) -> (logits, hidden).
    """
    acc1 = approx_matmul_pallas(x_enc, w1, cfg) + (ref.decode_sm(b1)[None, :] << 7)
    hidden = ref.saturate_activation(acc1)
    acc2 = approx_matmul_pallas(hidden, w2, cfg) + (ref.decode_sm(b2)[None, :] << 7)
    return acc2, hidden


def predict_q(logits) -> np.ndarray:
    """Argmax over 21-bit accumulators; ties resolve to the lowest index
    (matching the hardware maximum-value comparator chain)."""
    return np.asarray(jnp.argmax(jnp.asarray(logits), axis=-1))


def accuracy_q(params_q, x_enc, labels, cfg, batch: int = 2048, use_pallas=False):
    """Classification accuracy of the quantized pipeline."""
    n = len(x_enc)
    correct = 0
    for lo in range(0, n, batch):
        xb = x_enc[lo : lo + batch]
        if use_pallas:
            logits, _ = forward_q_pallas(
                xb,
                params_q["w1"],
                params_q["b1"],
                params_q["w2"],
                params_q["b2"],
                cfg,
            )
        else:
            logits, _ = forward_q_ref(params_q, xb, cfg)
        correct += int(np.sum(predict_q(logits) == np.asarray(labels[lo : lo + batch])))
    return correct / n

"""Layer-1 Pallas kernel: error-configurable approximate MAC matmul.

This is the compute hot-spot of the paper's system: every weighted sum
in the MLP runs through the error-configurable approximate multiplier.
The kernel computes one batch-tile of ``x_enc @ w_enc`` where each
scalar multiply is the bit-level approximate multiplier from
``amul_spec`` and the accumulation is exact, mirroring the hardware MAC
(multiplier array -> sign XOR -> add/sub accumulator).

Hardware adaptation (GPU/ASIC -> TPU thinking, see DESIGN.md):
the paper's knob gates partial-product *columns* of a 7x7 array
multiplier.  On a TPU the analogous structure is a bit-plane
decomposition: the kernel materialises the 13 partial-product column
planes as vector ops in VMEM and selects per-column exact/approximate
compression with the runtime ``cfg`` scalar, so one compiled executable
serves all 33 configurations — exactly like the taped-out circuit.

The kernel is lowered with ``interpret=True`` so the AOT HLO contains
plain vector ops executable by any PJRT backend (the rust CPU client);
real-TPU Mosaic lowering is a compile-only target in this image.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import amul_spec as spec

MAG_MAX = spec.MAG_MAX
DEFAULT_BLOCK_B = 16


def decode_levels(cfg):
    """Per-column levels from the config scalar, in plain jnp bit ops.

    This is the decoder ROM in front of the column-gating drivers.  It
    runs *outside* the Pallas kernel: the xla_extension 0.5.1 runtime
    the rust loader embeds mis-executes a dynamic scalar index into a
    kernel operand ref (the lookup silently returns garbage), whereas
    plain-HLO bit arithmetic round-trips exactly — see
    DESIGN.md §AOT-gotchas.  Returns a (13,) int32 vector.
    """
    cfg = jnp.asarray(cfg, dtype=jnp.int32)
    mask = jnp.maximum(cfg - 1, 0)
    levels = []
    for k in range(spec.N_COLS):
        lv = jnp.int32(spec.BASE_LEVELS.get(k, 0))
        for g, incs in enumerate(spec.BIT_INCREMENTS):
            if k in incs:
                lv = lv + ((mask >> g) & 1) * jnp.int32(incs[k])
        lv = jnp.minimum(lv, spec.LEVEL_MAX)
        levels.append(jnp.where(cfg == 0, jnp.int32(0), lv))
    return jnp.stack(levels)


def _approx_mul_planes(x, w, levels):
    """Elementwise approximate multiply of magnitude planes.

    x, w: int32 arrays (broadcastable), magnitudes in [0, 127].
    levels: (13,) traced int32 column levels.
    """
    total = x * 0 + w * 0  # broadcast-shaped zero
    for k in range(spec.N_COLS):
        pps = [((x >> i) & 1) & ((w >> j) & 1) for (i, j) in spec.COLUMN_PPS[k]]
        exact = functools.reduce(lambda u, v: u + v, pps)
        pair = None
        for p in range(0, len(pps) - 1, 2):
            t = pps[p] | pps[p + 1]
            pair = t if pair is None else pair + t
        if len(pps) % 2:
            pair = pps[-1] if pair is None else pair + pps[-1]
        orall = functools.reduce(lambda u, v: u | v, pps)
        lv = levels[k]
        contrib = jnp.where(lv == 0, exact, jnp.where(lv == 1, pair, orall))
        total = total + (contrib << k)
    return total


def _matmul_kernel(x_ref, w_ref, levels_ref, o_ref):
    """Pallas kernel body: one batch tile of the approximate matmul."""
    x = x_ref[...]  # (TB, I) int32 sign-magnitude
    w = w_ref[...]  # (I, J) int32 sign-magnitude
    levels = levels_ref[...]  # (13,) decoded column levels
    xm = (x & MAG_MAX)[:, :, None]  # (TB, I, 1)
    wm = (w & MAG_MAX)[None, :, :]  # (1, I, J)
    sign = ((x >> 7)[:, :, None] ^ (w >> 7)[None, :, :]) & 1
    mag = _approx_mul_planes(xm, wm, levels)  # (TB, I, J)
    prod = jnp.where(sign == 1, -mag, mag)
    o_ref[...] = jnp.sum(prod, axis=1, dtype=jnp.int32)


def approx_matmul_pallas(x_enc, w_enc, cfg, *, block_b: int = DEFAULT_BLOCK_B):
    """Approximate sign-magnitude matmul via the Pallas kernel.

    Args:
      x_enc: (B, I) int32 sign-magnitude inputs.
      w_enc: (I, J) int32 sign-magnitude weights.
      cfg: scalar int32 configuration in [0, 32].
      block_b: batch tile size (B is padded to a multiple of it).

    Returns: (B, J) int32 exact-accumulated approximate products.
    """
    x_enc = jnp.asarray(x_enc, dtype=jnp.int32)
    w_enc = jnp.asarray(w_enc, dtype=jnp.int32)
    b, i = x_enc.shape
    i2, j = w_enc.shape
    assert i == i2, f"inner dims mismatch: {i} vs {i2}"
    levels = decode_levels(cfg)

    tb = min(block_b, b) if b > 0 else 1
    pad = (-b) % tb
    if pad:
        x_enc = jnp.pad(x_enc, ((0, pad), (0, 0)))
    nb = x_enc.shape[0] // tb

    out = pl.pallas_call(
        _matmul_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((tb, i), lambda g: (g, 0)),
            pl.BlockSpec((i, j), lambda g: (0, 0)),
            pl.BlockSpec((spec.N_COLS,), lambda g: (0,)),
        ],
        out_specs=pl.BlockSpec((tb, j), lambda g: (g, 0)),
        out_shape=jax.ShapeDtypeStruct((x_enc.shape[0], j), jnp.int32),
        interpret=True,
    )(x_enc, w_enc, levels)
    return out[:b]

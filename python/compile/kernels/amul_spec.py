"""Frozen specification of the error-configurable approximate multiplier.

This module is the single source of truth for the multiplier's bit-level
behaviour.  Three independent implementations must agree with it exactly:

  * ``ref.py``            — vectorized pure-jnp oracle (used by pytest)
  * ``approx_mul.py``     — the Pallas kernel lowered into the AOT HLO
  * ``rust/src/amul/``    — the bit-level rust model driving the
                            cycle-accurate datapath simulator

Design
------
The paper's MAC multiplies 8-bit sign-magnitude operands: 1 sign bit and
N = 7 magnitude bits.  Signs are handled by a single XOR outside the
array, so the array itself is a 7x7 *unsigned* multiplier with
2N - 1 = 13 partial-product columns (weights 2^0 .. 2^12).

A 6-bit error-control input selects configuration 0 (accurate) or one of
32 approximate configurations (1..32).  Approximation is applied per
partial-product column at one of three levels, in the spirit of the
carry-disregarding / approximate-compressor designs the paper builds on
(refs [14], [16], [17]):

  level 0 — exact: full adder tree, carries propagate.
  level 1 — pairwise-OR compressor: consecutive partial products are
            OR-ed in pairs (a 2:1 approximate compressor); the reduced
            set is then summed exactly.  Half the column's adder cells
            are gated off.
  level 2 — full-OR, carry-disregarding: the column collapses to a
            single OR of all its partial products and injects no
            carries.  All the column's adder cells are gated off.

Configuration c >= 1 maps to the 5-bit mask m = c - 1.  The column
levels are::

    lv[1] = 2, lv[2] = 1                      (base, every approx cfg)
    m bit 0  ->  lv[2] += 1
    m bit 1  ->  lv[3] += 2
    m bit 2  ->  lv[4] += 2
    m bit 3  ->  lv[5] += 2
    m bit 4  ->  lv[6] += 1, lv[7] += 1
    (all levels saturate at 2)

Higher mask bits gate more (and wider) columns, so the mask value tracks
both the injected error and the saved power — this is the "dynamic power
control" knob the paper exposes.

Exhaustive error statistics of this scheme over all 128x128 operand
pairs (computed by ``python/tools/tune_amul.py`` and locked in
``tests/test_amul_spec.py``):

    ER    min  9.375 %   max 63.84 %   avg 47.9 %    (paper:  9.96 / 61.83 / 43.56)
    MRED  min  0.0425 %  max  2.99 %   avg  1.52 %   (paper:  0.055 / 3.68 / 2.13)
    NMED  min  0.0023 %  max  0.427 %  avg  0.215 %  (paper:  0.0028 / 0.364 / 0.224)
"""

from __future__ import annotations

N_BITS = 7  # magnitude bits per operand
MAG_MAX = (1 << N_BITS) - 1  # 127
N_COLS = 2 * N_BITS - 1  # 13 partial-product columns
N_CONFIGS = 33  # accurate (0) + 32 approximate (1..32)

# (column, increment) effects of each mask bit, and the always-on base.
BASE_LEVELS = {1: 2, 2: 1}
BIT_INCREMENTS = [
    {2: 1},  # mask bit 0
    {3: 2},  # mask bit 1
    {4: 2},  # mask bit 2
    {5: 2},  # mask bit 3
    {6: 1, 7: 1},  # mask bit 4
]
LEVEL_MAX = 2

# Partial products of column k, as (i, j) bit-index pairs with i + j = k,
# ordered by ascending i.  The pairwise-OR compressor (level 1) pairs them
# in this order: (pp0|pp1), (pp2|pp3), ..., with an odd leftover passed
# through.  This ordering is part of the frozen spec.
COLUMN_PPS = [
    [(i, k - i) for i in range(N_BITS) if 0 <= k - i < N_BITS] for k in range(N_COLS)
]


def column_levels(cfg: int) -> list[int]:
    """Per-column approximation level for configuration ``cfg`` (0..32)."""
    if not 0 <= cfg < N_CONFIGS:
        raise ValueError(f"cfg must be in [0, {N_CONFIGS}), got {cfg}")
    levels = [0] * N_COLS
    if cfg == 0:
        return levels
    for col, lv in BASE_LEVELS.items():
        levels[col] = lv
    mask = cfg - 1
    for g, incs in enumerate(BIT_INCREMENTS):
        if (mask >> g) & 1:
            for col, d in incs.items():
                levels[col] = min(LEVEL_MAX, levels[col] + d)
    return levels


def mul7_approx(a: int, b: int, cfg: int) -> int:
    """Approximate 7x7 unsigned multiply (scalar golden model).

    ``a`` and ``b`` are magnitudes in [0, 127]; result is a 14-bit
    magnitude.  Exact for cfg == 0.
    """
    if not 0 <= a <= MAG_MAX or not 0 <= b <= MAG_MAX:
        raise ValueError("operands must be 7-bit magnitudes")
    levels = column_levels(cfg)
    total = 0
    for k in range(N_COLS):
        pps = [((a >> i) & 1) & ((b >> j) & 1) for (i, j) in COLUMN_PPS[k]]
        lv = levels[k]
        if lv == 0:
            contrib = sum(pps)
        elif lv == 1:
            contrib = 0
            for p in range(0, len(pps) - 1, 2):
                contrib += pps[p] | pps[p + 1]
            if len(pps) % 2:
                contrib += pps[-1]
        else:
            contrib = 0
            for p in pps:
                contrib |= p
        total += contrib << k
    return total


def mul8_sm_approx(x: int, w: int, cfg: int) -> int:
    """Approximate signed multiply of 8-bit sign-magnitude operands.

    ``x`` and ``w`` are raw 8-bit encodings (MSB = sign, low 7 bits =
    magnitude).  Returns the signed integer product (15-bit range).
    The sign is the XOR of the operand signs; a zero magnitude always
    yields +0, matching the hardware comparison logic.
    """
    sx, mx = (x >> 7) & 1, x & MAG_MAX
    sw, mw = (w >> 7) & 1, w & MAG_MAX
    mag = mul7_approx(mx, mw, cfg)
    return -mag if (sx ^ sw) and mag != 0 else mag


def encode_sm(v: int) -> int:
    """Encode a signed integer in [-127, 127] as 8-bit sign-magnitude."""
    if not -MAG_MAX <= v <= MAG_MAX:
        raise ValueError(f"value {v} out of sign-magnitude range")
    return (0x80 | -v) if v < 0 else v


def decode_sm(enc: int) -> int:
    """Decode an 8-bit sign-magnitude encoding to a signed integer."""
    mag = enc & MAG_MAX
    return -mag if (enc >> 7) & 1 else mag


def exhaustive_metrics(cfg: int) -> tuple[float, float, float]:
    """(ER %, MRED %, NMED %) over all 128x128 magnitude pairs."""
    import numpy as np

    a = np.arange(128, dtype=np.int64)[:, None]
    b = np.arange(128, dtype=np.int64)[None, :]
    exact = a * b
    approx = mul7_approx_np(a, b, cfg)
    err = np.abs(approx - exact)
    er = float(np.mean(err != 0) * 100.0)
    nz = exact != 0
    mred = float(np.mean(err[nz] / exact[nz]) * 100.0)
    nmed = float(np.mean(err) / (MAG_MAX * MAG_MAX) * 100.0)
    return er, mred, nmed


def mul7_approx_np(a, b, cfg: int):
    """Vectorized numpy twin of :func:`mul7_approx` (broadcasts a, b)."""
    import numpy as np

    levels = column_levels(cfg)
    total = np.zeros(np.broadcast_shapes(np.shape(a), np.shape(b)), dtype=np.int64)
    a = np.asarray(a, dtype=np.int64)
    b = np.asarray(b, dtype=np.int64)
    for k in range(N_COLS):
        pps = [((a >> i) & 1) * ((b >> j) & 1) for (i, j) in COLUMN_PPS[k]]
        lv = levels[k]
        if lv == 0:
            contrib = sum(pps)
        elif lv == 1:
            contrib = np.zeros_like(total)
            for p in range(0, len(pps) - 1, 2):
                contrib = contrib + (pps[p] | pps[p + 1])
            if len(pps) % 2:
                contrib = contrib + pps[-1]
        else:
            contrib = np.zeros_like(total)
            for p in pps:
                contrib = contrib | p
        total = total + (contrib << k)
    return total

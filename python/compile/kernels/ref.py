"""Pure-jnp oracle for the approximate multiplier and the quantized MLP.

Everything here is reference semantics: straightforward, vectorized,
and independent of the Pallas kernel in ``approx_mul.py``.  pytest
asserts the Pallas kernel matches these functions bit-for-bit, and the
rust datapath simulator is cross-checked against golden vectors
generated from this module.

Fixed-point convention (shared with the rust simulator)
-------------------------------------------------------
  value      encoding                         scale
  --------   ------------------------------   -------
  input x    8-bit sign-magnitude (sign = 0)  x = x_q / 128
  weight w   8-bit sign-magnitude             w = dec(w_q) / 128
  bias b     8-bit sign-magnitude             b = dec(b_q) / 128
  product    15-bit signed                    x*w * 128^2
  acc        21-bit signed                    pre-activation * 128^2
  hidden h   8-bit, sign = 0 after ReLU       h = h_q / 128

The bias is left-shifted 7 bits into the accumulator domain before the
activation, and the saturation stage maps the 21-bit accumulator back to
8 bits via an arithmetic right shift by 7 and a clamp to [0, 127]
(ReLU folds into the clamp's lower bound).  Output-layer logits are the
raw 21-bit accumulators; the argmax circuit operates on those directly.
"""

from __future__ import annotations

import jax.numpy as jnp

from . import amul_spec as spec

MAG_MAX = spec.MAG_MAX


def _column_levels_traced(cfg):
    """Per-column approximation levels with ``cfg`` a traced int32 scalar."""
    cfg = jnp.asarray(cfg, dtype=jnp.int32)
    mask = jnp.maximum(cfg - 1, 0)
    levels = []
    for k in range(spec.N_COLS):
        lv = jnp.int32(spec.BASE_LEVELS.get(k, 0))
        for g, incs in enumerate(spec.BIT_INCREMENTS):
            if k in incs:
                bit = (mask >> g) & 1
                lv = lv + bit * jnp.int32(incs[k])
        lv = jnp.minimum(lv, spec.LEVEL_MAX)
        # configuration 0 is exact everywhere
        levels.append(jnp.where(cfg == 0, jnp.int32(0), lv))
    return levels


def mul7_approx(a, b, cfg):
    """Vectorized approximate 7x7 unsigned multiply.

    ``a``/``b``: int32 arrays of magnitudes in [0, 127] (broadcastable);
    ``cfg``: scalar int32 configuration in [0, 32].  Returns int32.
    """
    a = jnp.asarray(a, dtype=jnp.int32)
    b = jnp.asarray(b, dtype=jnp.int32)
    levels = _column_levels_traced(cfg)
    total = jnp.zeros(jnp.broadcast_shapes(a.shape, b.shape), dtype=jnp.int32)
    for k in range(spec.N_COLS):
        pps = [((a >> i) & 1) & ((b >> j) & 1) for (i, j) in spec.COLUMN_PPS[k]]
        exact = sum(pps)
        pair = jnp.zeros_like(total)
        for p in range(0, len(pps) - 1, 2):
            pair = pair + (pps[p] | pps[p + 1])
        if len(pps) % 2:
            pair = pair + pps[-1]
        orall = jnp.zeros_like(total)
        for p in pps:
            orall = orall | p
        lv = levels[k]
        contrib = jnp.where(lv == 0, exact, jnp.where(lv == 1, pair, orall))
        total = total + (contrib << k)
    return total


def mul8_sm_approx(x_enc, w_enc, cfg):
    """Vectorized signed multiply of 8-bit sign-magnitude encodings."""
    x_enc = jnp.asarray(x_enc, dtype=jnp.int32)
    w_enc = jnp.asarray(w_enc, dtype=jnp.int32)
    sign = ((x_enc >> 7) ^ (w_enc >> 7)) & 1
    mag = mul7_approx(x_enc & MAG_MAX, w_enc & MAG_MAX, cfg)
    return jnp.where(sign == 1, -mag, mag)


def approx_matmul(x_enc, w_enc, cfg):
    """Approximate sign-magnitude matmul: (B, I) x (I, J) -> (B, J) int32.

    Every scalar product uses the error-configurable multiplier; the
    accumulation is exact (the hardware accumulator adds/subtracts
    full-width), matching the paper's MAC structure.
    """
    x_enc = jnp.asarray(x_enc, dtype=jnp.int32)[:, :, None]  # (B, I, 1)
    w_enc = jnp.asarray(w_enc, dtype=jnp.int32)[None, :, :]  # (1, I, J)
    prod = mul8_sm_approx(x_enc, w_enc, cfg)  # (B, I, J)
    return jnp.sum(prod, axis=1, dtype=jnp.int32)


def decode_sm(enc):
    """Vectorized sign-magnitude decode."""
    enc = jnp.asarray(enc, dtype=jnp.int32)
    mag = enc & MAG_MAX
    return jnp.where((enc >> 7) & 1 == 1, -mag, mag)


def encode_sm(v):
    """Vectorized sign-magnitude encode of signed ints in [-127, 127]."""
    v = jnp.asarray(v, dtype=jnp.int32)
    return jnp.where(v < 0, 0x80 | (-v), v)


def saturate_activation(acc):
    """ReLU + 21->8-bit saturation: clamp(acc >> 7, 0, 127)."""
    return jnp.clip(jnp.asarray(acc, dtype=jnp.int32) >> 7, 0, MAG_MAX)


def mlp_forward_q_sched(x_enc, w1_enc, b1_enc, w2_enc, b2_enc, cfg_l0, cfg_l1):
    """Quantized MLP forward with a per-layer configuration schedule.

    Layer 0 (hidden) runs ``cfg_l0``, layer 1 (output) runs ``cfg_l1``
    — the python twin of the rust ``ConfigSchedule::PerLayer`` path.
    ``mlp_forward_q`` is the uniform special case; the per-layer
    schedule sweep in ``compile.aot`` uses this directly so both sweeps
    share one forward-pass definition.
    """
    acc1 = approx_matmul(x_enc, w1_enc, cfg_l0) + (decode_sm(b1_enc)[None, :] << 7)
    hidden = saturate_activation(acc1)
    acc2 = approx_matmul(hidden, w2_enc, cfg_l1) + (decode_sm(b2_enc)[None, :] << 7)
    return acc2, hidden


def mlp_forward_q(x_enc, w1_enc, b1_enc, w2_enc, b2_enc, cfg):
    """Quantized hardware-faithful MLP forward pass (uniform config).

    Args:
      x_enc:  (B, 62) int32 sign-magnitude inputs (sign bit 0).
      w1_enc: (62, 30), b1_enc: (30,) — hidden layer parameters.
      w2_enc: (30, 10), b2_enc: (10,) — output layer parameters.
      cfg: scalar int32 multiplier configuration in [0, 32].

    Returns:
      (logits, hidden): logits (B, 10) int32 21-bit accumulators,
      hidden (B, 30) int32 8-bit saturated activations.
    """
    return mlp_forward_q_sched(x_enc, w1_enc, b1_enc, w2_enc, b2_enc, cfg, cfg)


def mlp_forward_f32(x, w1, b1, w2, b2):
    """Float reference MLP (training-time semantics)."""
    h = jnp.maximum(x @ w1 + b1, 0.0)
    return h @ w2 + b2, h

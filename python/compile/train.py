"""Training loop for the 62-30-10 MLP (build-time only, pure JAX).

No optax in this environment, so Adam is implemented inline.  Training
uses the hardware-aware float surrogate (clipped ReLU at the saturation
ceiling, parameters projected into the sign-magnitude representable
range after every step) so post-training quantization is nearly
lossless.

Run standalone:  python -m compile.train --outdir ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import dataset as ds
from . import model


def cross_entropy(params, x, y):
    logits = model.forward_f32(params, x)
    logz = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logz, y[:, None], axis=1))


@jax.jit
def _adam_step(params, m, v, t, x, y, lr):
    beta1, beta2, eps = 0.9, 0.999, 1e-8
    loss, grads = jax.value_and_grad(cross_entropy)(params, x, y)
    new_params, new_m, new_v = {}, {}, {}
    for k in params:
        new_m[k] = beta1 * m[k] + (1 - beta1) * grads[k]
        new_v[k] = beta2 * v[k] + (1 - beta2) * grads[k] ** 2
        mhat = new_m[k] / (1 - beta1**t)
        vhat = new_v[k] / (1 - beta2**t)
        new_params[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
    new_params = model.clip_params(new_params)
    return new_params, new_m, new_v, loss


def train(
    x_train,
    y_train,
    x_test,
    y_test,
    *,
    epochs: int = 20,
    batch: int = 256,
    lr: float = 2e-3,
    seed: int = 0,
    log=print,
):
    """Train and return (params, history)."""
    params = model.init_params(seed)
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(v_) for k, v_ in params.items()}
    rng = np.random.default_rng(seed)
    n = len(x_train)
    x_train = jnp.asarray(x_train)
    y_train = jnp.asarray(y_train)
    history = []
    t = 0
    t0 = time.time()
    for epoch in range(epochs):
        order = rng.permutation(n)
        epoch_loss = 0.0
        steps = 0
        for lo in range(0, n - batch + 1, batch):
            idx = order[lo : lo + batch]
            t += 1
            params, m, v, loss = _adam_step(
                params, m, v, float(t), x_train[idx], y_train[idx], lr
            )
            epoch_loss += float(loss)
            steps += 1
        acc = float(
            np.mean(
                model.predict_q(model.forward_f32(params, jnp.asarray(x_test)))
                == np.asarray(y_test)
            )
        )
        history.append(
            {
                "epoch": epoch,
                "loss": epoch_loss / max(steps, 1),
                "test_acc_f32": acc,
                "elapsed_s": time.time() - t0,
            }
        )
        log(
            f"epoch {epoch:3d}  loss {history[-1]['loss']:.4f}  "
            f"f32 test acc {acc * 100:.2f}%"
        )
    return params, history


def features_from_images(images, feat_idx):
    """28x28 uint8 -> float features in [0, 1) at 7-bit resolution.

    The float value is exactly mag/128 with mag = pixel >> 1, so the
    float surrogate sees precisely what the quantized pipeline sees.
    """
    feats = ds.reduce_features(images, feat_idx)
    mags = ds.quantize_inputs(feats)
    return mags.astype(np.float32) / 128.0, mags


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--epochs", type=int, default=20)
    ap.add_argument("--n-train", type=int, default=60000)
    ap.add_argument("--n-test", type=int, default=10000)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    tr_i, tr_l, te_i, te_l, feat = ds.build_cached(
        args.outdir, args.n_train, args.n_test
    )
    x_train, _ = features_from_images(tr_i, feat)
    x_test, test_mags = features_from_images(te_i, feat)
    params, history = train(
        x_train, tr_l.astype(np.int32), x_test, te_l.astype(np.int32),
        epochs=args.epochs, seed=args.seed,
    )
    params_q = model.quantize_params(params)
    acc_q = model.accuracy_q(params_q, test_mags, te_l, 0)
    print(f"quantized accurate-mode test accuracy: {acc_q * 100:.2f}%")

    os.makedirs(args.outdir, exist_ok=True)
    out = {
        "w1": np.asarray(params["w1"]).tolist(),
        "b1": np.asarray(params["b1"]).tolist(),
        "w2": np.asarray(params["w2"]).tolist(),
        "b2": np.asarray(params["b2"]).tolist(),
        "history": history,
        "quantized_accurate_acc": acc_q,
    }
    with open(os.path.join(args.outdir, "weights_f32.json"), "w") as f:
        json.dump(out, f)
    print(f"wrote {args.outdir}/weights_f32.json")


if __name__ == "__main__":
    main()

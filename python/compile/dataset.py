"""Synthetic handwritten-digit dataset (MNIST substitute) + feature reduction.

The paper evaluates on MNIST.  This environment has no network access,
so we generate a procedural handwritten-digit dataset with the same
shape contract (28x28 uint8 images, labels 0..9, 60k train / 10k test).
Each sample starts from a coarse digit glyph and goes through a random
affine warp (rotation, scale, shear, translation), stroke-thickness
variation, blur, additive noise and occlusion — calibrated so the
paper's tiny 62-30-10 MLP lands near the paper's ~89.7% accuracy in
accurate mode (see DESIGN.md §Substitutions).

Feature reduction: the paper reduces 784 inputs to 62 but does not give
the method.  We use train-set variance ranking with a spatial
de-clustering constraint (greedily keep the highest-variance pixels at
Chebyshev distance >= 2 from already-selected ones) — a wiring-only
reduction implementable in hardware as pixel selection, consistent with
the paper's area argument.  The frozen indices ship in the artifact
manifest so the rust loader applies the identical reduction.

Everything is deterministic given the seed.
"""

from __future__ import annotations

import os

import numpy as np
from scipy import ndimage

IMG = 28
N_FEATURES = 62
N_CLASSES = 10

# 7x5 coarse glyphs, one per digit (classic seven-segment-ish font).
_GLYPHS = {
    0: ["01110", "10001", "10011", "10101", "11001", "10001", "01110"],
    1: ["00100", "01100", "00100", "00100", "00100", "00100", "01110"],
    2: ["01110", "10001", "00001", "00110", "01000", "10000", "11111"],
    3: ["01110", "10001", "00001", "00110", "00001", "10001", "01110"],
    4: ["00010", "00110", "01010", "10010", "11111", "00010", "00010"],
    5: ["11111", "10000", "11110", "00001", "00001", "10001", "01110"],
    6: ["00110", "01000", "10000", "11110", "10001", "10001", "01110"],
    7: ["11111", "00001", "00010", "00100", "01000", "01000", "01000"],
    8: ["01110", "10001", "10001", "01110", "10001", "10001", "01110"],
    9: ["01110", "10001", "10001", "01111", "00001", "00010", "01100"],
}


def _glyph_canvas(digit: int) -> np.ndarray:
    """Upscale the 7x5 glyph onto a float 28x28 canvas."""
    g = np.array([[float(c) for c in row] for row in _GLYPHS[digit]], dtype=np.float32)
    # 7x5 -> 21x15 block upscale, centred on the canvas
    up = np.kron(g, np.ones((3, 3), dtype=np.float32))
    canvas = np.zeros((IMG, IMG), dtype=np.float32)
    r0 = (IMG - up.shape[0]) // 2
    c0 = (IMG - up.shape[1]) // 2
    canvas[r0 : r0 + up.shape[0], c0 : c0 + up.shape[1]] = up
    return canvas


# Distortion strengths, calibrated so the quantized accurate-mode MLP
# accuracy lands near the paper's 89.67% (see python/tools/calibrate.py).
DIFFICULTY = {
    "rot_deg": 19.0,
    "scale_lo": 0.78,
    "scale_hi": 1.22,
    "shear": 0.22,
    "shift_px": 3.1,
    "thickness_sigma_lo": 0.5,
    "thickness_sigma_hi": 1.22,
    "noise_sigma": 0.125,
    "occlusion_p": 0.25,
    "occlusion_size": 7,
    "contrast_lo": 0.56,
    "contrast_hi": 1.0,
}


def _render_one(digit: int, rng: np.random.Generator, d: dict) -> np.ndarray:
    base = _glyph_canvas(digit)
    # stroke thickness: blur then re-threshold softly
    sigma = rng.uniform(d["thickness_sigma_lo"], d["thickness_sigma_hi"])
    img = ndimage.gaussian_filter(base, sigma)
    m = img.max()
    if m > 0:
        img = img / m
    # random affine about the image centre
    theta = np.deg2rad(rng.uniform(-d["rot_deg"], d["rot_deg"]))
    scale = rng.uniform(d["scale_lo"], d["scale_hi"])
    shear = rng.uniform(-d["shear"], d["shear"])
    rot = np.array(
        [[np.cos(theta), -np.sin(theta)], [np.sin(theta), np.cos(theta)]],
        dtype=np.float64,
    )
    shr = np.array([[1.0, shear], [0.0, 1.0]])
    mat = (rot @ shr) / scale
    centre = np.array([IMG / 2 - 0.5, IMG / 2 - 0.5])
    shift = rng.uniform(-d["shift_px"], d["shift_px"], size=2)
    offset = centre - mat @ (centre + shift)
    img = ndimage.affine_transform(img, mat, offset=offset, order=1, mode="constant")
    # occlusion patch
    if rng.uniform() < d["occlusion_p"]:
        s = d["occlusion_size"]
        r = rng.integers(0, IMG - s)
        c = rng.integers(0, IMG - s)
        img[r : r + s, c : c + s] = 0.0
    # contrast + additive noise
    img = img * rng.uniform(d["contrast_lo"], d["contrast_hi"])
    img = img + rng.normal(0.0, d["noise_sigma"], img.shape)
    return np.clip(img, 0.0, 1.0)


def generate(n: int, seed: int, difficulty: dict | None = None):
    """Generate ``n`` samples; returns (images uint8 (n,28,28), labels uint8)."""
    d = dict(DIFFICULTY)
    if difficulty:
        d.update(difficulty)
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, N_CLASSES, size=n).astype(np.uint8)
    images = np.empty((n, IMG, IMG), dtype=np.uint8)
    for idx in range(n):
        img = _render_one(int(labels[idx]), rng, d)
        images[idx] = np.round(img * 255.0).astype(np.uint8)
    return images, labels


def select_features(train_images: np.ndarray, k: int = N_FEATURES) -> np.ndarray:
    """Variance-ranked, spatially de-clustered pixel selection (wiring-only).

    Returns ``k`` flat pixel indices into the 784-vector, sorted ascending.
    """
    flat = train_images.reshape(len(train_images), -1).astype(np.float32) / 255.0
    var = flat.var(axis=0)
    order = np.argsort(-var)
    chosen: list[int] = []
    taken = np.zeros((IMG, IMG), dtype=bool)
    for pix in order:
        r, c = divmod(int(pix), IMG)
        r0, r1 = max(0, r - 1), min(IMG, r + 2)
        c0, c1 = max(0, c - 1), min(IMG, c + 2)
        if taken[r0:r1, c0:c1].any():
            continue
        chosen.append(int(pix))
        taken[r, c] = True
        if len(chosen) == k:
            break
    if len(chosen) < k:  # relax the constraint if the image is exhausted
        for pix in order:
            if int(pix) not in chosen:
                chosen.append(int(pix))
                if len(chosen) == k:
                    break
    return np.array(sorted(chosen), dtype=np.int32)


def reduce_features(images: np.ndarray, indices: np.ndarray) -> np.ndarray:
    """784 -> 62 pixel selection; returns uint8 (n, 62)."""
    return images.reshape(len(images), -1)[:, indices]


def quantize_inputs(feat_u8: np.ndarray) -> np.ndarray:
    """uint8 [0,255] features -> 7-bit magnitudes [0,127] (sign bit 0).

    The hardware input port is 8-bit sign-magnitude; pixels are
    non-negative so the top bit is 0 and the magnitude is pixel >> 1.
    """
    return (feat_u8.astype(np.int32)) >> 1


# ---------------------------------------------------------------------------
# idx-format serialization (same container format as the original MNIST
# distribution, so the rust loader doubles as a real-MNIST loader).
# ---------------------------------------------------------------------------


def write_idx_images(path: str, images: np.ndarray) -> None:
    n, rows, cols = images.shape
    with open(path, "wb") as f:
        f.write((0x00000803).to_bytes(4, "big"))
        f.write(n.to_bytes(4, "big"))
        f.write(rows.to_bytes(4, "big"))
        f.write(cols.to_bytes(4, "big"))
        f.write(images.tobytes())


def write_idx_labels(path: str, labels: np.ndarray) -> None:
    with open(path, "wb") as f:
        f.write((0x00000801).to_bytes(4, "big"))
        f.write(len(labels).to_bytes(4, "big"))
        f.write(labels.astype(np.uint8).tobytes())


def read_idx_images(path: str) -> np.ndarray:
    with open(path, "rb") as f:
        magic = int.from_bytes(f.read(4), "big")
        assert magic == 0x00000803, f"bad magic {magic:#x}"
        n = int.from_bytes(f.read(4), "big")
        rows = int.from_bytes(f.read(4), "big")
        cols = int.from_bytes(f.read(4), "big")
        return np.frombuffer(f.read(), dtype=np.uint8).reshape(n, rows, cols)


def read_idx_labels(path: str) -> np.ndarray:
    with open(path, "rb") as f:
        magic = int.from_bytes(f.read(4), "big")
        assert magic == 0x00000801, f"bad magic {magic:#x}"
        n = int.from_bytes(f.read(4), "big")
        return np.frombuffer(f.read(), dtype=np.uint8)


def build_cached(
    outdir: str,
    n_train: int = 60000,
    n_test: int = 10000,
    seed: int = 2024,
    force: bool = False,
):
    """Generate (or load) the dataset artifacts in ``outdir``.

    Returns (train_images, train_labels, test_images, test_labels,
    feature_indices).
    """
    paths = {
        "train_img": os.path.join(outdir, "train-images.idx3"),
        "train_lbl": os.path.join(outdir, "train-labels.idx1"),
        "test_img": os.path.join(outdir, "test-images.idx3"),
        "test_lbl": os.path.join(outdir, "test-labels.idx1"),
        "feat": os.path.join(outdir, "feature-indices.txt"),
    }
    if not force and all(os.path.exists(p) for p in paths.values()):
        tr_i = read_idx_images(paths["train_img"])
        tr_l = read_idx_labels(paths["train_lbl"])
        te_i = read_idx_images(paths["test_img"])
        te_l = read_idx_labels(paths["test_lbl"])
        feat = np.loadtxt(paths["feat"], dtype=np.int32)
        if len(tr_i) == n_train and len(te_i) == n_test:
            return tr_i, tr_l, te_i, te_l, feat
    os.makedirs(outdir, exist_ok=True)
    tr_i, tr_l = generate(n_train, seed)
    te_i, te_l = generate(n_test, seed + 1)
    feat = select_features(tr_i)
    write_idx_images(paths["train_img"], tr_i)
    write_idx_labels(paths["train_lbl"], tr_l)
    write_idx_images(paths["test_img"], te_i)
    write_idx_labels(paths["test_lbl"], te_l)
    np.savetxt(paths["feat"], feat, fmt="%d")
    return tr_i, tr_l, te_i, te_l, feat

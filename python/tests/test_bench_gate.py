"""The CI bench-regression gate over BENCH_forward.json / BENCH_serve.json
(and the BENCH_pipeline.json artifact of the same `forward` kind)."""

import json

from tools import bench_gate


def artifact(kernel_speedup=2.5, batch_speedup=4.0, sweep_speedup=2.0, **extra):
    doc = {
        "schema_version": 2,
        "bench": "forward",
        "rows": [
            {
                "topology": "62-30-10",
                "kernel_speedup": kernel_speedup,
                "batch_speedup": batch_speedup,
                "sweep_speedup": sweep_speedup,
                "batch_per_sec": 1e6 * kernel_speedup,
                "batch_signed_per_sec": 1e6,
                "per_image_per_sec": 5e5,
            }
        ],
    }
    doc.update(extra)
    return doc


def write(tmp_path, name, doc):
    p = tmp_path / name
    p.write_text(json.dumps(doc))
    return str(p)


class TestInRunInvariants:
    def test_healthy_artifact_passes(self, tmp_path):
        fresh = write(tmp_path, "fresh.json", artifact())
        assert bench_gate.run([fresh]) == 0

    def test_kernel_regression_fails(self, tmp_path):
        # tiled kernels slower than the PR-4 path beyond tolerance
        fresh = write(tmp_path, "fresh.json", artifact(kernel_speedup=0.7))
        assert bench_gate.run([fresh]) == 1

    def test_sweep_regression_fails(self, tmp_path):
        fresh = write(tmp_path, "fresh.json", artifact(sweep_speedup=0.5))
        assert bench_gate.run([fresh]) == 1

    def test_tolerance_allows_noise(self, tmp_path):
        # 5% under 1.0x is inside the default 10% tolerance
        fresh = write(tmp_path, "fresh.json", artifact(kernel_speedup=0.95))
        assert bench_gate.run([fresh]) == 0

    def test_wrong_artifact_kind_rejected(self, tmp_path):
        fresh = write(tmp_path, "fresh.json", {"bench": "cycle_batch"})
        assert bench_gate.run([fresh]) == 2


class TestBaselineComparison:
    def test_drop_beyond_tolerance_fails(self, tmp_path):
        base = write(tmp_path, "base.json", artifact(kernel_speedup=3.0))
        fresh = write(tmp_path, "fresh.json", artifact(kernel_speedup=2.0))
        assert bench_gate.run([fresh, "--baseline", base]) == 1

    def test_within_tolerance_passes(self, tmp_path):
        base = write(tmp_path, "base.json", artifact(kernel_speedup=2.5))
        fresh = write(tmp_path, "fresh.json", artifact(kernel_speedup=2.3))
        assert bench_gate.run([fresh, "--baseline", base]) == 0

    def test_improvement_passes(self, tmp_path):
        base = write(tmp_path, "base.json", artifact(batch_speedup=3.0))
        fresh = write(tmp_path, "fresh.json", artifact(batch_speedup=9.0))
        assert bench_gate.run([fresh, "--baseline", base]) == 0

    def test_pending_baseline_skips_comparison(self, tmp_path):
        base = write(
            tmp_path, "base.json", artifact(pending_measurement=True, rows=[])
        )
        # fresh would fail the comparison if it ran; the stub skips it
        fresh = write(tmp_path, "fresh.json", artifact())
        assert bench_gate.run([fresh, "--baseline", base]) == 0

    def test_shrunken_coverage_fails(self, tmp_path):
        # a topology in the baseline with no fresh measurement must not
        # pass silently
        base_doc = artifact()
        base_doc["rows"].append(dict(base_doc["rows"][0], topology="62-20-20-10"))
        base = write(tmp_path, "base.json", base_doc)
        fresh = write(tmp_path, "fresh.json", artifact())
        assert bench_gate.run([fresh, "--baseline", base]) == 1

    def test_missing_baseline_is_not_fatal(self, tmp_path):
        fresh = write(tmp_path, "fresh.json", artifact())
        missing = str(tmp_path / "nope.json")
        assert bench_gate.run([fresh, "--baseline", missing]) == 0

    def test_absolute_mode_compares_throughput(self, tmp_path):
        base = write(tmp_path, "base.json", artifact())
        doc = artifact()
        doc["rows"][0]["batch_per_sec"] = 1e5  # 25x drop, ratios unchanged
        fresh = write(tmp_path, "fresh.json", doc)
        assert bench_gate.run([fresh, "--baseline", base]) == 0
        assert bench_gate.run([fresh, "--baseline", base, "--absolute"]) == 1

    def test_write_baseline_round_trip(self, tmp_path):
        fresh = write(tmp_path, "fresh.json", artifact())
        target = str(tmp_path / "baseline.json")
        assert bench_gate.run([fresh, "--write-baseline", target]) == 0
        assert bench_gate.run([fresh, "--baseline", target]) == 0

    def test_committed_stub_is_valid_for_the_gate(self, tmp_path):
        # the repository-root baseline must parse and behave as pending
        import pathlib

        root = pathlib.Path(__file__).resolve().parents[2]
        committed = root / "BENCH_forward.json"
        doc = json.loads(committed.read_text())
        assert doc["bench"] == "forward"
        fresh = write(tmp_path, "fresh.json", artifact())
        assert bench_gate.run([fresh, "--baseline", str(committed)]) == 0


def pipeline_artifact(pipeline_speedup=1.4, fallback=False, **extra):
    """`ecmac bench --pipeline` output: the same `forward` artifact kind,
    rows keyed by topology with the pipeline comparison columns."""
    doc = {
        "schema_version": 2,
        "bench": "forward",
        "mode": "pipeline",
        "rows": [
            {
                "topology": "784-128-64-10",
                "batch": 512,
                "batch_par_per_sec": 1e5,
                "pipeline_per_sec": 1e5 * pipeline_speedup,
                "pipeline_speedup": pipeline_speedup,
                "plan": "[0..1]x7 | [1..3]x1 @ micro 16",
                "stages": 2,
                "workers": 8,
                "pipeline_fallback": fallback,
                "bit_exact": True,
            }
        ],
    }
    doc.update(extra)
    return doc


class TestPipelineInRunInvariants:
    def test_pipeline_beats_row_partition_passes(self, tmp_path):
        fresh = write(tmp_path, "fresh.json", pipeline_artifact(pipeline_speedup=1.4))
        assert bench_gate.run([fresh]) == 0

    def test_pipeline_slower_than_row_partition_fails(self, tmp_path):
        # the acceptance invariant: where the planner engaged, the
        # stage pipeline must at least match the row partition
        fresh = write(tmp_path, "fresh.json", pipeline_artifact(pipeline_speedup=0.8))
        assert bench_gate.run([fresh]) == 1

    def test_fallback_rows_are_exempt(self, tmp_path):
        # planner declined (shallow topology / too few cores): both
        # sides ran the same code, the ratio is noise
        fresh = write(
            tmp_path,
            "fresh.json",
            pipeline_artifact(pipeline_speedup=0.5, fallback=True),
        )
        assert bench_gate.run([fresh]) == 0

    def test_tolerance_allows_noise(self, tmp_path):
        fresh = write(tmp_path, "fresh.json", pipeline_artifact(pipeline_speedup=0.95))
        assert bench_gate.run([fresh]) == 0

    def test_forward_rows_without_pipeline_columns_unaffected(self, tmp_path):
        # plain --forward artifacts carry no pipeline_speedup; the new
        # invariant must not fire on them
        fresh = write(tmp_path, "fresh.json", artifact())
        assert bench_gate.run([fresh]) == 0

    def test_baseline_ratio_comparison_covers_pipeline_speedup(self, tmp_path):
        base = write(tmp_path, "base.json", pipeline_artifact(pipeline_speedup=2.0))
        fresh = write(tmp_path, "fresh.json", pipeline_artifact(pipeline_speedup=1.4))
        assert bench_gate.run([fresh, "--baseline", base]) == 1
        improved = write(
            tmp_path, "improved.json", pipeline_artifact(pipeline_speedup=2.2)
        )
        assert bench_gate.run([improved, "--baseline", base]) == 0


def serve_artifact(adaptive_speedup=2.0, answered=4000, **extra):
    doc = {
        "schema_version": 1,
        "bench": "serve",
        "requests": 4000,
        "rows": [
            {
                "policy": "fixed:16",
                "mode": "closed:8",
                "offered_rps": 5e4,
                "batch1_throughput_rps": 2e4,
                "throughput_rps": 2e4 * adaptive_speedup,
                "adaptive_speedup": adaptive_speedup,
                "p50_us": 120.0,
                "p95_us": 600.0,
                "p99_us": 900.0,
                "mean_batch": 7.5,
                "energy_per_image_nj": 80.0,
                "answered": answered,
                "rejected": 0,
                "errors": 0,
            }
        ],
    }
    doc.update(extra)
    return doc


class TestServeInRunInvariants:
    def test_healthy_artifact_passes(self, tmp_path):
        fresh = write(tmp_path, "fresh.json", serve_artifact())
        assert bench_gate.run([fresh]) == 0

    def test_adaptive_slower_than_batch1_fails(self, tmp_path):
        # the acceptance invariant: adaptive batching must at least
        # match the pinned batch=1 front-end at equal offered load
        fresh = write(tmp_path, "fresh.json", serve_artifact(adaptive_speedup=0.7))
        assert bench_gate.run([fresh]) == 1

    def test_tolerance_allows_noise(self, tmp_path):
        fresh = write(tmp_path, "fresh.json", serve_artifact(adaptive_speedup=0.95))
        assert bench_gate.run([fresh]) == 0

    def test_zero_answered_fails(self, tmp_path):
        # a run that rejected/errored everything must not pass just
        # because the speedup column looks fine
        fresh = write(tmp_path, "fresh.json", serve_artifact(answered=0))
        assert bench_gate.run([fresh]) == 1

    def test_empty_rows_fail(self, tmp_path):
        fresh = write(tmp_path, "fresh.json", serve_artifact(rows=[]))
        assert bench_gate.run([fresh]) == 1


def check(name, verdict="proved", detail="bound holds"):
    return {"name": name, "verdict": verdict, "detail": detail}


def analyze_artifact(**extra):
    """`ecmac analyze --json` output: rows keyed by id, each carrying
    range checks plus nested per-plan liveness checks and a summary."""
    range_checks = [
        check("layer0.i32-acc"),
        check("cfg0.gather-rows"),
        check("energy-counters"),
    ]
    plan_checks = [check("plan.residency"), check("plan.model")]
    doc = {
        "schema_version": 1,
        "bench": "analyze",
        "max_workers": 8,
        "batch": 512,
        "rows": [
            {
                "id": "62-30-10@cfg0",
                "topology": "62-30-10",
                "schedule": "cfg0",
                "checks": range_checks,
                "layers": [],
                "plans": [{"workers": 8, "batch": 512, "checks": plan_checks}],
                "summary": {"proved": 5, "refuted": 0, "unknown": 0},
            }
        ],
        "summary": {"proved": 5, "refuted": 0, "unknown": 0},
    }
    doc.update(extra)
    return doc


class TestAnalyzeInvariants:
    def test_fully_proved_artifact_passes(self, tmp_path):
        fresh = write(tmp_path, "fresh.json", analyze_artifact())
        assert bench_gate.run([fresh]) == 0

    def test_refuted_check_fails(self, tmp_path):
        doc = analyze_artifact()
        doc["rows"][0]["checks"][0] = check(
            "layer0.i32-acc", "refuted", "violated bound: i32-acc"
        )
        doc["rows"][0]["summary"] = {"proved": 4, "refuted": 1, "unknown": 0}
        doc["summary"] = {"proved": 4, "refuted": 1, "unknown": 0}
        fresh = write(tmp_path, "fresh.json", doc)
        assert bench_gate.run([fresh]) == 1

    def test_unknown_check_fails(self, tmp_path):
        # an undecided analysis is a gate failure, not a skip
        doc = analyze_artifact()
        doc["rows"][0]["plans"][0]["checks"][1] = check(
            "plan.model", "unknown", "state cap hit"
        )
        doc["rows"][0]["summary"] = {"proved": 4, "refuted": 0, "unknown": 1}
        doc["summary"] = {"proved": 4, "refuted": 0, "unknown": 1}
        fresh = write(tmp_path, "fresh.json", doc)
        assert bench_gate.run([fresh]) == 1

    def test_nested_plan_refutation_fails(self, tmp_path):
        # liveness failures live inside the plans array, not the
        # top-level checks — the gate must walk both
        doc = analyze_artifact()
        doc["rows"][0]["plans"][0]["checks"][0] = check(
            "stage2.residency", "refuted", "violated bound: residency"
        )
        doc["rows"][0]["summary"] = {"proved": 4, "refuted": 1, "unknown": 0}
        doc["summary"] = {"proved": 4, "refuted": 1, "unknown": 0}
        fresh = write(tmp_path, "fresh.json", doc)
        assert bench_gate.run([fresh]) == 1

    def test_inconsistent_summary_fails(self, tmp_path):
        # a summary claiming more proofs than its checks hold is a
        # broken artifact, not a pass
        doc = analyze_artifact()
        doc["rows"][0]["summary"] = {"proved": 99, "refuted": 0, "unknown": 0}
        fresh = write(tmp_path, "fresh.json", doc)
        assert bench_gate.run([fresh]) == 1

    def test_empty_rows_fail(self, tmp_path):
        fresh = write(tmp_path, "fresh.json", analyze_artifact(rows=[]))
        assert bench_gate.run([fresh]) == 1

    def test_grand_summary_refutations_fail_even_with_clean_rows(self, tmp_path):
        doc = analyze_artifact()
        doc["summary"] = {"proved": 5, "refuted": 1, "unknown": 0}
        fresh = write(tmp_path, "fresh.json", doc)
        assert bench_gate.run([fresh]) == 1


class TestServeBaselineComparison:
    def test_speedup_drop_beyond_tolerance_fails(self, tmp_path):
        base = write(tmp_path, "base.json", serve_artifact(adaptive_speedup=3.0))
        fresh = write(tmp_path, "fresh.json", serve_artifact(adaptive_speedup=2.0))
        assert bench_gate.run([fresh, "--baseline", base]) == 1

    def test_improvement_passes(self, tmp_path):
        base = write(tmp_path, "base.json", serve_artifact(adaptive_speedup=2.0))
        fresh = write(tmp_path, "fresh.json", serve_artifact(adaptive_speedup=4.0))
        assert bench_gate.run([fresh, "--baseline", base]) == 0

    def test_absolute_mode_compares_throughput(self, tmp_path):
        base = write(tmp_path, "base.json", serve_artifact())
        doc = serve_artifact()
        doc["rows"][0]["throughput_rps"] = 1e3  # big drop, ratio unchanged
        fresh = write(tmp_path, "fresh.json", doc)
        assert bench_gate.run([fresh, "--baseline", base]) == 0
        assert bench_gate.run([fresh, "--baseline", base, "--absolute"]) == 1

    def test_shrunken_policy_coverage_fails(self, tmp_path):
        base_doc = serve_artifact()
        base_doc["rows"].append(dict(base_doc["rows"][0], policy="budget:5.0"))
        base = write(tmp_path, "base.json", base_doc)
        fresh = write(tmp_path, "fresh.json", serve_artifact())
        assert bench_gate.run([fresh, "--baseline", base]) == 1

    def test_kind_mismatch_fails(self, tmp_path):
        # wiring the forward baseline into the serve gate is a CI bug,
        # not a silent skip
        base = write(tmp_path, "base.json", artifact())
        fresh = write(tmp_path, "fresh.json", serve_artifact())
        assert bench_gate.run([fresh, "--baseline", base]) == 1

    def test_pending_baseline_skips_comparison(self, tmp_path):
        base = write(
            tmp_path, "base.json", serve_artifact(pending_measurement=True, rows=[])
        )
        fresh = write(tmp_path, "fresh.json", serve_artifact())
        assert bench_gate.run([fresh, "--baseline", base]) == 0

    def test_write_baseline_round_trip(self, tmp_path):
        fresh = write(tmp_path, "fresh.json", serve_artifact())
        target = str(tmp_path / "baseline.json")
        assert bench_gate.run([fresh, "--write-baseline", target]) == 0
        assert bench_gate.run([fresh, "--baseline", target]) == 0

    def test_committed_stub_is_valid_for_the_gate(self, tmp_path):
        import pathlib

        root = pathlib.Path(__file__).resolve().parents[2]
        committed = root / "BENCH_serve.json"
        doc = json.loads(committed.read_text())
        assert doc["bench"] == "serve"
        fresh = write(tmp_path, "fresh.json", serve_artifact())
        assert bench_gate.run([fresh, "--baseline", str(committed)]) == 0


def chaos_class(name="acc-transient", outcome="detected_degraded", **extra):
    row = {
        "class": name,
        "fault": "bit-30 flip in one hidden-layer accumulator",
        "outcome": outcome,
        "detail": "envelope violations 1, next request served",
        "replies": 2,
        "unresolved": 0,
    }
    row.update(extra)
    return row


def chaos_artifact(**extra):
    """`ecmac chaos --json` output: one entry per injected fault class
    plus an outcome tally."""
    classes = [
        chaos_class("table-stuck-benign", "masked"),
        chaos_class("acc-transient", "detected_degraded"),
        chaos_class("stage-panic", "failed_fast"),
    ]
    doc = {
        "bench": "chaos",
        "seed": 20260807,
        "classes": classes,
        "summary": {
            "masked": 1,
            "detected_degraded": 1,
            "failed_fast": 1,
            "silent": 0,
            "hung": 0,
            "total": 3,
        },
    }
    doc.update(extra)
    return doc


class TestChaosInvariants:
    def test_contained_campaign_passes(self, tmp_path):
        fresh = write(tmp_path, "fresh.json", chaos_artifact())
        assert bench_gate.run([fresh]) == 0

    def test_silent_class_fails(self, tmp_path):
        doc = chaos_artifact()
        doc["classes"][1]["outcome"] = "silent"
        doc["summary"]["detected_degraded"] = 0
        doc["summary"]["silent"] = 1
        fresh = write(tmp_path, "fresh.json", doc)
        assert bench_gate.run([fresh]) == 1

    def test_hung_class_fails(self, tmp_path):
        doc = chaos_artifact()
        doc["classes"][2]["outcome"] = "hung"
        doc["summary"]["failed_fast"] = 0
        doc["summary"]["hung"] = 1
        fresh = write(tmp_path, "fresh.json", doc)
        assert bench_gate.run([fresh]) == 1

    def test_unresolved_replies_fail_even_when_contained(self, tmp_path):
        # a masked fault that left a caller hanging is still a hang
        doc = chaos_artifact()
        doc["classes"][0]["unresolved"] = 1
        fresh = write(tmp_path, "fresh.json", doc)
        assert bench_gate.run([fresh]) == 1

    def test_unknown_outcome_fails(self, tmp_path):
        doc = chaos_artifact()
        doc["classes"][0]["outcome"] = "mostly-fine"
        fresh = write(tmp_path, "fresh.json", doc)
        assert bench_gate.run([fresh]) == 1

    def test_inconsistent_summary_fails(self, tmp_path):
        # a tally hiding a silent class behind clean counts is a broken
        # artifact, not a pass
        doc = chaos_artifact()
        doc["summary"]["masked"] = 2
        fresh = write(tmp_path, "fresh.json", doc)
        assert bench_gate.run([fresh]) == 1

    def test_total_mismatch_fails(self, tmp_path):
        doc = chaos_artifact()
        doc["summary"]["total"] = 99
        fresh = write(tmp_path, "fresh.json", doc)
        assert bench_gate.run([fresh]) == 1

    def test_empty_campaign_fails(self, tmp_path):
        doc = chaos_artifact(classes=[])
        doc["summary"] = {
            "masked": 0,
            "detected_degraded": 0,
            "failed_fast": 0,
            "silent": 0,
            "hung": 0,
            "total": 0,
        }
        fresh = write(tmp_path, "fresh.json", doc)
        assert bench_gate.run([fresh]) == 1


def sentinel_class(name="drift-shadow", outcome="detected_recovered", **extra):
    row = {
        "class": name,
        "scenario": "every 3rd prediction silently corrupted",
        "outcome": outcome,
        "detail": "breach after 42 shadow samples, schedule restored",
        "replies": 60,
        "unresolved": 0,
    }
    row.update(extra)
    return row


def sentinel_artifact(**extra):
    """`ecmac sentinel --json` output: one entry per audit class plus an
    outcome tally; the clean class carries the online-vs-offline
    disagreement cross-check."""
    classes = [
        sentinel_class(
            "clean-estimate",
            "clean",
            estimate={"observed": 0.104, "predicted": 0.083, "tolerance": 0.05},
        ),
        sentinel_class("drift-shadow", "detected_recovered"),
        sentinel_class("table-scrub", "detected_recovered"),
    ]
    doc = {
        "bench": "sentinel",
        "seed": 20260807,
        "classes": classes,
        "summary": {
            "clean": 1,
            "detected_recovered": 2,
            "unrecovered": 0,
            "silent": 0,
            "hung": 0,
            "total": 3,
        },
    }
    doc.update(extra)
    return doc


class TestSentinelInvariants:
    def test_resolved_campaign_passes(self, tmp_path):
        fresh = write(tmp_path, "fresh.json", sentinel_artifact())
        assert bench_gate.run([fresh]) == 0

    def test_each_bad_outcome_fails(self, tmp_path):
        for i, bad in enumerate(("unrecovered", "silent", "hung")):
            doc = sentinel_artifact()
            doc["classes"][1]["outcome"] = bad
            doc["summary"]["detected_recovered"] = 1
            doc["summary"][bad] = 1
            fresh = write(tmp_path, f"fresh{i}.json", doc)
            assert bench_gate.run([fresh]) == 1, bad

    def test_unresolved_replies_fail_even_when_recovered(self, tmp_path):
        # a recovery that left a caller hanging is still a hang
        doc = sentinel_artifact()
        doc["classes"][2]["unresolved"] = 1
        fresh = write(tmp_path, "fresh.json", doc)
        assert bench_gate.run([fresh]) == 1

    def test_unknown_outcome_fails(self, tmp_path):
        doc = sentinel_artifact()
        doc["classes"][0]["outcome"] = "probably-fine"
        fresh = write(tmp_path, "fresh.json", doc)
        assert bench_gate.run([fresh]) == 1

    def test_estimate_outside_its_tolerance_fails(self, tmp_path):
        # the class may report "clean", but a miscalibrated shadow
        # estimate voids the accuracy cross-check the audit exists for
        doc = sentinel_artifact()
        doc["classes"][0]["estimate"]["observed"] = 0.30
        fresh = write(tmp_path, "fresh.json", doc)
        assert bench_gate.run([fresh]) == 1

    def test_estimate_missing_a_field_fails(self, tmp_path):
        doc = sentinel_artifact()
        del doc["classes"][0]["estimate"]["predicted"]
        fresh = write(tmp_path, "fresh.json", doc)
        assert bench_gate.run([fresh]) == 1

    def test_classes_without_estimates_are_exempt(self, tmp_path):
        doc = sentinel_artifact()
        del doc["classes"][0]["estimate"]
        fresh = write(tmp_path, "fresh.json", doc)
        assert bench_gate.run([fresh]) == 0

    def test_inconsistent_summary_fails(self, tmp_path):
        doc = sentinel_artifact()
        doc["summary"]["clean"] = 2
        fresh = write(tmp_path, "fresh.json", doc)
        assert bench_gate.run([fresh]) == 1

    def test_total_mismatch_fails(self, tmp_path):
        doc = sentinel_artifact()
        doc["summary"]["total"] = 99
        fresh = write(tmp_path, "fresh.json", doc)
        assert bench_gate.run([fresh]) == 1

    def test_empty_campaign_fails(self, tmp_path):
        doc = sentinel_artifact(classes=[])
        doc["summary"] = {
            "clean": 0,
            "detected_recovered": 0,
            "unrecovered": 0,
            "silent": 0,
            "hung": 0,
            "total": 0,
        }
        fresh = write(tmp_path, "fresh.json", doc)
        assert bench_gate.run([fresh]) == 1

"""Training loop + AOT export machinery (small, fast configurations)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, dataset as ds, model, train as tr
from compile.kernels import amul_spec as spec


@pytest.fixture(scope="module")
def tiny_data():
    imgs, labels = ds.generate(600, seed=20)
    feat = ds.select_features(imgs)
    x, mags = tr.features_from_images(imgs, feat)
    return x, mags, labels.astype(np.int32)


class TestTraining:
    def test_loss_decreases(self, tiny_data):
        x, _, y = tiny_data
        params, hist = tr.train(
            x[:500], y[:500], x[500:], y[500:], epochs=3, batch=64, log=lambda *_: None
        )
        assert hist[-1]["loss"] < hist[0]["loss"]

    def test_params_stay_in_representable_range(self, tiny_data):
        x, _, y = tiny_data
        params, _ = tr.train(
            x[:300], y[:300], x[300:400], y[300:400], epochs=2, batch=64,
            log=lambda *_: None,
        )
        for k, v in params.items():
            assert np.abs(np.asarray(v)).max() <= model.W_MAX + 1e-6, k

    def test_accuracy_beats_chance(self, tiny_data):
        x, mags, y = tiny_data
        params, _ = tr.train(
            x[:500], y[:500], x[500:], y[500:], epochs=4, batch=64,
            log=lambda *_: None,
        )
        q = model.quantize_params(params)
        acc = model.accuracy_q(q, mags[500:], y[500:], 0)
        assert acc > 0.22  # far above the 10% chance floor even on 500 samples

    def test_features_from_images_scale_contract(self, tiny_data):
        x, mags, _ = tiny_data
        # float features must be exactly mag / 128
        np.testing.assert_allclose(x, mags.astype(np.float32) / 128.0)


class TestAotHelpers:
    def test_to_hlo_text_produces_module(self):
        def fn(a, b):
            return (a @ b,)

        s = jax.ShapeDtypeStruct((2, 2), jnp.float32)
        text = aot.to_hlo_text(jax.jit(fn).lower(s, s))
        assert text.startswith("HloModule")
        assert "ENTRY" in text

    def test_golden_multiplier_vectors_match_spec(self):
        vecs = aot.golden_multiplier_vectors(n_per_cfg=16, seed=1)
        assert len(vecs) == spec.N_CONFIGS
        for v in vecs:
            assert v["levels"] == spec.column_levels(v["cfg"])
            for a, b, p in zip(v["a"], v["b"], v["product"]):
                assert spec.mul8_sm_approx(int(a), int(b), v["cfg"]) == p

    def test_amul_metric_table_shape(self):
        rows = aot.amul_metric_table()
        assert len(rows) == spec.N_CONFIGS
        assert rows[0]["er_pct"] == 0.0
        assert rows[32]["er_pct"] > 60.0

    def test_export_approx_hlo_writes_parseable_text(self, tmp_path):
        name = aot.export_approx_hlo(str(tmp_path), batch=2)
        text = open(os.path.join(str(tmp_path), name)).read()
        assert text.startswith("HloModule")
        # all six parameters must survive into the entry layout
        header = text.splitlines()[0]
        assert header.count("s32") >= 6

    def test_golden_mlp_vectors_consistent(self, tiny_data):
        _, mags, y = tiny_data
        params, _ = tr.train(
            jnp.asarray(mags[:200], jnp.float32) / 128.0,
            y[:200],
            jnp.asarray(mags[200:260], jnp.float32) / 128.0,
            y[200:260],
            epochs=1,
            batch=32,
            log=lambda *_: None,
        )
        q = model.quantize_params(params)
        g = aot.golden_mlp_vectors(q, mags[:4], y[:4], cfgs=(0, 32))
        assert len(g["cases"]) == 2
        for case in g["cases"]:
            logits, hidden = model.forward_q_ref(q, mags[:4], case["cfg"])
            np.testing.assert_array_equal(np.asarray(logits), np.array(case["logits"]))
            np.testing.assert_array_equal(np.asarray(hidden), np.array(case["hidden"]))

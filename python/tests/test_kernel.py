"""Pallas kernel vs pure-jnp oracle — the core L1 correctness signal.

The kernel must match ``ref.approx_matmul`` bit-for-bit for every
configuration, shape, and padding situation; hypothesis sweeps the shape
space.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import amul_spec as spec
from compile.kernels import ref
from compile.kernels.approx_mul import approx_matmul_pallas, decode_levels


def rand_sm(rng, shape):
    """Random sign-magnitude encodings (full 8-bit range)."""
    return rng.integers(0, 256, shape).astype(np.int32)


class TestDecodeLevels:
    def test_matches_spec_for_all_configs(self):
        for cfg in range(spec.N_CONFIGS):
            got = np.asarray(decode_levels(cfg)).tolist()
            assert got == spec.column_levels(cfg), cfg


class TestKernelParity:
    @pytest.mark.parametrize("cfg", [0, 1, 2, 9, 16, 17, 31, 32])
    def test_matches_ref_fixed_shapes(self, cfg):
        rng = np.random.default_rng(cfg)
        x = rand_sm(rng, (5, 62))
        w = rand_sm(rng, (62, 30))
        got = np.asarray(approx_matmul_pallas(x, w, cfg))
        want = np.asarray(ref.approx_matmul(x, w, cfg))
        np.testing.assert_array_equal(got, want)

    def test_cfg0_equals_exact_matmul(self):
        rng = np.random.default_rng(7)
        x = rand_sm(rng, (4, 62))
        w = rand_sm(rng, (62, 30))
        got = np.asarray(approx_matmul_pallas(x, w, 0))
        xd = np.asarray(ref.decode_sm(x))
        wd = np.asarray(ref.decode_sm(w))
        np.testing.assert_array_equal(got, xd @ wd)

    @given(
        b=st.integers(1, 40),
        i=st.integers(1, 70),
        j=st.integers(1, 32),
        cfg=st.integers(0, 32),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=30, deadline=None)
    def test_matches_ref_hypothesis_shapes(self, b, i, j, cfg, seed):
        rng = np.random.default_rng(seed)
        x = rand_sm(rng, (b, i))
        w = rand_sm(rng, (i, j))
        got = np.asarray(approx_matmul_pallas(x, w, cfg))
        want = np.asarray(ref.approx_matmul(x, w, cfg))
        np.testing.assert_array_equal(got, want)

    @pytest.mark.parametrize("b", [1, 3, 15, 16, 17, 33])
    def test_padding_boundaries(self, b):
        """Batch sizes around the block boundary must round-trip."""
        rng = np.random.default_rng(b)
        x = rand_sm(rng, (b, 62))
        w = rand_sm(rng, (62, 30))
        got = np.asarray(approx_matmul_pallas(x, w, 17))
        want = np.asarray(ref.approx_matmul(x, w, 17))
        assert got.shape == (b, 30)
        np.testing.assert_array_equal(got, want)

    def test_block_size_invariance(self):
        rng = np.random.default_rng(3)
        x = rand_sm(rng, (10, 62))
        w = rand_sm(rng, (62, 30))
        a = np.asarray(approx_matmul_pallas(x, w, 5, block_b=4))
        b = np.asarray(approx_matmul_pallas(x, w, 5, block_b=16))
        np.testing.assert_array_equal(a, b)

    def test_traced_cfg_under_jit(self):
        """cfg must work as a runtime (traced) argument — the AOT path."""
        import jax
        import jax.numpy as jnp

        rng = np.random.default_rng(11)
        x = rand_sm(rng, (2, 62))
        w = rand_sm(rng, (62, 30))

        @jax.jit
        def fwd(x, w, cfg):
            return approx_matmul_pallas(x, w, cfg)

        for cfg in [0, 13, 32]:
            got = np.asarray(fwd(x, w, jnp.int32(cfg)))
            want = np.asarray(ref.approx_matmul(x, w, cfg))
            np.testing.assert_array_equal(got, want)


class TestRefInternalConsistency:
    """ref.py against the scalar spec (transitively validates the kernel)."""

    @given(
        cfg=st.integers(0, 32),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=40, deadline=None)
    def test_ref_matmul_matches_scalar_spec(self, cfg, seed):
        rng = np.random.default_rng(seed)
        x = rand_sm(rng, (2, 7))
        w = rand_sm(rng, (7, 3))
        got = np.asarray(ref.approx_matmul(x, w, cfg))
        for b in range(2):
            for o in range(3):
                acc = sum(
                    spec.mul8_sm_approx(int(x[b, i]), int(w[i, o]), cfg)
                    for i in range(7)
                )
                assert got[b, o] == acc

    def test_saturate_activation(self):
        assert int(ref.saturate_activation(np.int32(-100))) == 0
        assert int(ref.saturate_activation(np.int32(127 << 7))) == 127
        assert int(ref.saturate_activation(np.int32(1 << 20))) == 127
        assert int(ref.saturate_activation(np.int32((5 << 7) + 127))) == 5

    @given(v=st.integers(-127, 127))
    @settings(max_examples=200, deadline=None)
    def test_encode_decode_vectorized(self, v):
        assert int(ref.decode_sm(ref.encode_sm(np.int32(v)))) == v

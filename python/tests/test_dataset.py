"""Synthetic dataset generator + feature reduction + idx container."""

import numpy as np
import pytest

from compile import dataset as ds


class TestGenerator:
    def test_shapes_and_dtypes(self):
        imgs, labels = ds.generate(50, seed=1)
        assert imgs.shape == (50, 28, 28)
        assert imgs.dtype == np.uint8
        assert labels.shape == (50,)
        assert set(np.unique(labels)).issubset(set(range(10)))

    def test_deterministic_given_seed(self):
        a_i, a_l = ds.generate(20, seed=42)
        b_i, b_l = ds.generate(20, seed=42)
        np.testing.assert_array_equal(a_i, b_i)
        np.testing.assert_array_equal(a_l, b_l)

    def test_different_seeds_differ(self):
        a_i, _ = ds.generate(20, seed=1)
        b_i, _ = ds.generate(20, seed=2)
        assert not np.array_equal(a_i, b_i)

    def test_images_have_signal(self):
        imgs, _ = ds.generate(30, seed=3)
        # every image should have some bright pixels (a digit)
        assert (imgs.reshape(30, -1).max(axis=1) > 100).all()

    def test_digits_are_distinguishable(self):
        """Mean images of distinct digits must differ substantially."""
        imgs, labels = ds.generate(400, seed=4)
        means = {}
        for d in range(10):
            sel = imgs[labels == d]
            if len(sel):
                means[d] = sel.mean(axis=0)
        keys = list(means)
        diffs = [
            np.abs(means[a] - means[b]).mean()
            for i, a in enumerate(keys)
            for b in keys[i + 1 :]
        ]
        assert min(diffs) > 3.0


class TestFeatureSelection:
    @pytest.fixture(scope="class")
    def images(self):
        return ds.generate(500, seed=5)[0]

    def test_selects_exactly_62_unique_sorted(self, images):
        idx = ds.select_features(images)
        assert len(idx) == 62
        assert len(set(idx.tolist())) == 62
        assert (np.diff(idx) > 0).all()
        assert idx.min() >= 0 and idx.max() < 784

    def test_declustering(self, images):
        """No two selected pixels within Chebyshev distance 1."""
        idx = ds.select_features(images)
        coords = [(int(p) // 28, int(p) % 28) for p in idx]
        for i, (r1, c1) in enumerate(coords):
            for r2, c2 in coords[i + 1 :]:
                assert max(abs(r1 - r2), abs(c1 - c2)) >= 2

    def test_selected_pixels_carry_variance(self, images):
        idx = ds.select_features(images)
        flat = images.reshape(len(images), -1).astype(np.float32) / 255.0
        var = flat.var(axis=0)
        # selected pixels should be far more informative than average
        assert var[idx].mean() > var.mean() * 1.5


class TestQuantizeReduce:
    def test_reduce_features_picks_columns(self):
        imgs = np.arange(2 * 784, dtype=np.uint8).reshape(2, 28, 28)
        idx = np.array([0, 10, 100], dtype=np.int32)
        out = ds.reduce_features(imgs, idx)
        assert out.shape == (2, 3)
        assert out[0, 1] == imgs.reshape(2, -1)[0, 10]

    def test_quantize_inputs_is_7bit(self):
        feats = np.array([[0, 1, 2, 254, 255]], dtype=np.uint8)
        q = ds.quantize_inputs(feats)
        assert q.tolist() == [[0, 0, 1, 127, 127]]


class TestIdxFormat:
    def test_images_roundtrip(self, tmp_path):
        imgs, labels = ds.generate(10, seed=6)
        p_i = str(tmp_path / "i.idx3")
        p_l = str(tmp_path / "l.idx1")
        ds.write_idx_images(p_i, imgs)
        ds.write_idx_labels(p_l, labels)
        np.testing.assert_array_equal(ds.read_idx_images(p_i), imgs)
        np.testing.assert_array_equal(ds.read_idx_labels(p_l), labels)

    def test_build_cached_reuses(self, tmp_path):
        out = str(tmp_path)
        r1 = ds.build_cached(out, n_train=30, n_test=10, seed=9)
        r2 = ds.build_cached(out, n_train=30, n_test=10, seed=9)
        np.testing.assert_array_equal(r1[0], r2[0])
        np.testing.assert_array_equal(r1[4], r2[4])

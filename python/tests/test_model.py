"""L2 model semantics: quantization, forward-pass parity, hardware limits."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import model
from compile.kernels import ref


def rand_params(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w1": rng.uniform(-0.9, 0.9, (model.N_INPUTS, model.N_HIDDEN)).astype(np.float32),
        "b1": rng.uniform(-0.5, 0.5, model.N_HIDDEN).astype(np.float32),
        "w2": rng.uniform(-0.9, 0.9, (model.N_HIDDEN, model.N_OUTPUTS)).astype(np.float32),
        "b2": rng.uniform(-0.5, 0.5, model.N_OUTPUTS).astype(np.float32),
    }


def rand_inputs(seed, n):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 128, (n, model.N_INPUTS)).astype(np.int32)


class TestQuantization:
    def test_encodings_are_valid_sign_magnitude(self):
        q = model.quantize_params(rand_params())
        for name, arr in q.items():
            a = np.asarray(arr)
            assert a.min() >= 0 and a.max() <= 255, name
            mags = a & 0x7F
            assert mags.max() <= 127, name

    def test_quantization_roundtrip_error_bounded(self):
        p = rand_params()
        q = model.quantize_params(p)
        w1_back = np.asarray(ref.decode_sm(q["w1"])) / 128.0
        assert np.abs(w1_back - p["w1"]).max() <= 0.5 / 128.0 + 1e-7

    @given(v=st.floats(-0.99, 0.99, allow_nan=False))
    @settings(max_examples=200, deadline=None)
    def test_scalar_quant_within_half_lsb(self, v):
        q = model.quantize_params(
            {
                "w1": np.full((model.N_INPUTS, model.N_HIDDEN), v, np.float32),
                "b1": np.zeros(model.N_HIDDEN, np.float32),
                "w2": np.zeros((model.N_HIDDEN, model.N_OUTPUTS), np.float32),
                "b2": np.zeros(model.N_OUTPUTS, np.float32),
            }
        )
        back = float(np.asarray(ref.decode_sm(q["w1"][0][0]))) / 128.0
        assert abs(back - v) <= 0.5 / 128.0 + 1e-7


class TestForwardParity:
    @pytest.mark.parametrize("cfg", [0, 9, 32])
    def test_pallas_forward_matches_ref(self, cfg):
        q = model.quantize_params(rand_params(3))
        x = rand_inputs(4, 5)
        ref_logits, ref_hidden = model.forward_q_ref(q, x, cfg)
        pl_logits, pl_hidden = model.forward_q_pallas(
            x, q["w1"], q["b1"], q["w2"], q["b2"], cfg
        )
        np.testing.assert_array_equal(np.asarray(ref_logits), np.asarray(pl_logits))
        np.testing.assert_array_equal(np.asarray(ref_hidden), np.asarray(pl_hidden))

    def test_hidden_respects_8bit_range(self):
        q = model.quantize_params(rand_params(5))
        x = rand_inputs(6, 16)
        _, hidden = model.forward_q_ref(q, x, 0)
        h = np.asarray(hidden)
        assert h.min() >= 0 and h.max() <= 127

    def test_logits_respect_21bit_range(self):
        q = model.quantize_params(rand_params(6))
        x = rand_inputs(7, 16)
        logits, _ = model.forward_q_ref(q, x, 0)
        l = np.asarray(logits)
        assert np.abs(l).max() < (1 << 20)

    def test_accuracy_helper_counts(self):
        q = model.quantize_params(rand_params(8))
        x = rand_inputs(9, 32)
        logits, _ = model.forward_q_ref(q, x, 0)
        labels = model.predict_q(logits)
        assert model.accuracy_q(q, x, labels, 0) == 1.0

    def test_float_surrogate_tracks_quantized(self):
        """The clipped-ReLU float model and the integer pipeline must
        agree closely (scale 1/128 quantization only)."""
        p = rand_params(10)
        q = model.quantize_params(p)
        x_q = rand_inputs(11, 64)
        x_f = x_q.astype(np.float32) / 128.0
        f_logits = np.asarray(model.forward_f32(p, x_f))
        q_logits = np.asarray(model.forward_q_ref(q, x_q, 0)[0]).astype(np.float64)
        q_scaled = q_logits / (128.0 * 128.0)
        # correlation must be extremely high even if absolute values
        # differ by quantization noise
        corr = np.corrcoef(f_logits.ravel(), q_scaled.ravel())[0, 1]
        assert corr > 0.999, corr

"""Tests freezing the approximate-multiplier specification.

These tests lock the bit-level behaviour and the exhaustive error
statistics of the scheme.  If any of them fail after an edit, the
multiplier no longer matches the golden vectors shipped to the rust
side — regenerate everything or revert.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import amul_spec as spec


class TestColumnStructure:
    def test_column_count(self):
        assert spec.N_COLS == 13
        assert len(spec.COLUMN_PPS) == 13

    def test_pp_counts_are_triangular(self):
        counts = [len(p) for p in spec.COLUMN_PPS]
        assert counts == [1, 2, 3, 4, 5, 6, 7, 6, 5, 4, 3, 2, 1]

    def test_pp_indices_valid(self):
        for k, pps in enumerate(spec.COLUMN_PPS):
            for i, j in pps:
                assert i + j == k
                assert 0 <= i < 7 and 0 <= j < 7

    def test_pp_order_ascending_i(self):
        for pps in spec.COLUMN_PPS:
            assert [i for i, _ in pps] == sorted(i for i, _ in pps)


class TestLevels:
    def test_cfg0_all_exact(self):
        assert spec.column_levels(0) == [0] * 13

    def test_cfg1_base_only(self):
        lv = spec.column_levels(1)
        assert lv[1] == 2 and lv[2] == 1
        assert all(lv[k] == 0 for k in range(13) if k not in (1, 2))

    def test_cfg32_max_approx(self):
        lv = spec.column_levels(32)
        assert lv == [0, 2, 2, 2, 2, 2, 1, 1, 0, 0, 0, 0, 0]

    def test_levels_bounded(self):
        for cfg in range(spec.N_CONFIGS):
            for l in spec.column_levels(cfg):
                assert 0 <= l <= spec.LEVEL_MAX

    def test_mask_bits_monotone_in_gated_columns(self):
        """Setting a mask bit never reduces any column's level."""
        for m in range(32):
            for g in range(5):
                if not (m >> g) & 1:
                    lo = spec.column_levels(1 + m)
                    hi = spec.column_levels(1 + (m | (1 << g)))
                    assert all(a <= b for a, b in zip(lo, hi))

    def test_invalid_cfg_raises(self):
        with pytest.raises(ValueError):
            spec.column_levels(33)
        with pytest.raises(ValueError):
            spec.column_levels(-1)


class TestScalarMultiplier:
    def test_cfg0_exact_exhaustive(self):
        for a in range(0, 128, 7):
            for b in range(128):
                assert spec.mul7_approx(a, b, 0) == a * b

    def test_zero_annihilates_all_configs(self):
        for cfg in range(spec.N_CONFIGS):
            for v in (0, 1, 64, 127):
                assert spec.mul7_approx(0, v, cfg) == 0
                assert spec.mul7_approx(v, 0, cfg) == 0

    def test_approx_error_bounded(self):
        """Approximation only loses carries/counts: result <= exact and
        the deficit is bounded by the sum of approximated column widths."""
        rng = np.random.default_rng(3)
        for cfg in range(1, spec.N_CONFIGS):
            levels = spec.column_levels(cfg)
            bound = sum(
                (len(spec.COLUMN_PPS[k]) - 1) << k
                for k in range(13)
                if levels[k] > 0
            )
            for _ in range(200):
                a, b = rng.integers(0, 128, 2)
                exact = int(a) * int(b)
                approx = spec.mul7_approx(int(a), int(b), cfg)
                assert approx <= exact
                assert exact - approx <= bound

    def test_commutative_accurate_mode(self):
        rng = np.random.default_rng(4)
        for _ in range(300):
            a, b = map(int, rng.integers(0, 128, 2))
            assert spec.mul7_approx(a, b, 0) == spec.mul7_approx(b, a, 0)

    def test_pairwise_or_levels_not_commutative(self):
        """Level-1 compressors pair partial products in i-order, so
        odd-sized columns break operand symmetry — a documented hardware
        property (operand roles are fixed: x = activation, w = weight).
        Locked here so an accidental "fix" on one side of the stack gets
        caught by the golden vectors."""
        asym = sum(
            spec.mul7_approx(a, b, 1) != spec.mul7_approx(b, a, 1)
            for a in range(0, 128, 3)
            for b in range(0, 128, 5)
        )
        assert asym > 0

    @given(
        a=st.integers(0, 127),
        b=st.integers(0, 127),
        cfg=st.integers(0, 32),
    )
    @settings(max_examples=300, deadline=None)
    def test_scalar_matches_numpy_twin(self, a, b, cfg):
        assert spec.mul7_approx(a, b, cfg) == int(spec.mul7_approx_np(a, b, cfg))


class TestSignMagnitude:
    @given(v=st.integers(-127, 127))
    @settings(max_examples=300, deadline=None)
    def test_encode_decode_roundtrip(self, v):
        assert spec.decode_sm(spec.encode_sm(v)) == v

    def test_encode_range_check(self):
        with pytest.raises(ValueError):
            spec.encode_sm(128)
        with pytest.raises(ValueError):
            spec.encode_sm(-128)

    @given(
        x=st.integers(-127, 127),
        w=st.integers(-127, 127),
    )
    @settings(max_examples=300, deadline=None)
    def test_signed_mul_cfg0_exact(self, x, w):
        enc_x, enc_w = spec.encode_sm(x), spec.encode_sm(w)
        assert spec.mul8_sm_approx(enc_x, enc_w, 0) == x * w

    def test_sign_xor(self):
        # (-a) * b == a * (-b) == -(a * b) for all configs
        for cfg in (0, 5, 32):
            p = spec.mul8_sm_approx(spec.encode_sm(100), spec.encode_sm(55), cfg)
            n1 = spec.mul8_sm_approx(spec.encode_sm(-100), spec.encode_sm(55), cfg)
            n2 = spec.mul8_sm_approx(spec.encode_sm(100), spec.encode_sm(-55), cfg)
            pp = spec.mul8_sm_approx(spec.encode_sm(-100), spec.encode_sm(-55), cfg)
            assert n1 == n2 == -p
            assert pp == p

    def test_negative_zero_normalised(self):
        # 0x80 encodes -0; products with zero magnitude are +0
        assert spec.mul8_sm_approx(0x80, spec.encode_sm(77), 0) == 0


class TestExhaustiveMetrics:
    """Lock the Table-I-shaped statistics of the frozen scheme."""

    @pytest.fixture(scope="class")
    def table(self):
        return [spec.exhaustive_metrics(cfg) for cfg in range(spec.N_CONFIGS)]

    def test_cfg0_no_error(self, table):
        assert table[0] == (0.0, 0.0, 0.0)

    def test_er_range(self, table):
        ers = [r[0] for r in table[1:]]
        assert min(ers) == pytest.approx(9.375, abs=0.01)
        assert max(ers) == pytest.approx(63.84, abs=0.05)

    def test_mred_range(self, table):
        mreds = [r[1] for r in table[1:]]
        assert min(mreds) == pytest.approx(0.0425, abs=0.001)
        assert max(mreds) == pytest.approx(2.994, abs=0.01)

    def test_nmed_range(self, table):
        nmeds = [r[2] for r in table[1:]]
        assert min(nmeds) == pytest.approx(0.00233, abs=0.0001)
        assert max(nmeds) == pytest.approx(0.4268, abs=0.005)

    def test_averages_near_paper(self, table):
        """The averages must stay in the paper's ballpark (Table I)."""
        ers = [r[0] for r in table[1:]]
        mreds = [r[1] for r in table[1:]]
        nmeds = [r[2] for r in table[1:]]
        assert 40.0 < np.mean(ers) < 55.0  # paper: 43.556
        assert 1.0 < np.mean(mreds) < 2.5  # paper: 2.125
        assert 0.15 < np.mean(nmeds) < 0.30  # paper: 0.224

    def test_nmed_weakly_increases_with_mask_weight(self, table):
        """More gating bits -> at least as much average error (NMED)."""
        by_weight = {}
        for cfg in range(1, 33):
            w = bin(cfg - 1).count("1")
            by_weight.setdefault(w, []).append(table[cfg][2])
        means = [np.mean(by_weight[w]) for w in sorted(by_weight)]
        assert all(a < b for a, b in zip(means, means[1:]))

//! End-to-end validation driver (DESIGN.md §Experiment-Index).
//!
//! Exercises the full system on the real workload and reports every
//! paper-vs-measured number in one run:
//!
//!   1. Table I — exhaustive multiplier error statistics.
//!   2. Full test-set accuracy for all 33 configurations (native
//!      bit-exact model, parallel across configs), cross-checked against
//!      the python-side sweep, plus PJRT and cycle-accurate spot checks.
//!   3. Power sweep — netlist switching profile on real operand traces,
//!      calibrated model, Figs 5/6/7 summary numbers.
//!   4. Area roll-up.
//!   5. A governed serving run (throughput/latency under dynamic power
//!      control).
//!
//! Run:  cargo run --release --example end_to_end

use ecmac::amul::{metrics, Config};
use ecmac::coordinator::governor::{AccuracyTable, Governor, Policy};
use ecmac::coordinator::{Backend, Coordinator, CoordinatorConfig, NativeBackend};
use ecmac::dataset::Dataset;
use ecmac::datapath::{DatapathSim, MacObserver, Network};
use ecmac::power::{MultiplierEnergyProfile, PowerModel};
use ecmac::weights::QuantWeights;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let t_start = Instant::now();
    let dir = ecmac::runtime::default_artifacts_dir();
    let ds = Dataset::load_test(&dir)?;
    let net = Network::new(QuantWeights::load_artifacts(&dir)?);
    println!("=== ecmac end-to-end validation ===");
    println!("test set: {} images\n", ds.len());

    // ------------------------------------------------------------ 1
    let stats = metrics::full_table();
    let t1 = metrics::table_i(&stats);
    println!("[1] Table I (multiplier error statistics, exhaustive)");
    println!(
        "    ER    min {:7.4}  max {:7.4}  avg {:7.3}   (paper  9.9609 / 61.8255 / 43.556)",
        t1.er_min, t1.er_max, t1.er_avg
    );
    println!(
        "    MRED  min {:7.4}  max {:7.4}  avg {:7.3}   (paper  0.0548 /  3.6840 /  2.125)",
        t1.mred_min, t1.mred_max, t1.mred_avg
    );
    println!(
        "    NMED  min {:7.4}  max {:7.4}  avg {:7.3}   (paper  0.0028 /  0.3643 /  0.224)\n",
        t1.nmed_min, t1.nmed_max, t1.nmed_avg
    );

    // ------------------------------------------------------------ 2
    println!("[2] full test-set accuracy, all 33 configurations (native)");
    let t0 = Instant::now();
    let configs: Vec<Config> = Config::all().collect();
    let accs = ecmac::util::threadpool::par_map(&configs, |_, &cfg| {
        net.accuracy(&ds.features, &ds.labels, cfg)
    });
    let eval_wall = t0.elapsed();
    let acc0 = accs[0];
    let worst = accs[1..].iter().cloned().fold(f64::MAX, f64::min);
    let avg = accs[1..].iter().sum::<f64>() / 32.0;
    println!(
        "    accurate {:.2}%   worst {:.2}%   avg(32) {:.2}%   (paper 89.67 / 88.75 / 89.11)",
        acc0 * 100.0,
        worst * 100.0,
        avg * 100.0
    );
    println!(
        "    drop worst vs accurate: {:.2} pts (paper 0.92)",
        (acc0 - worst) * 100.0
    );
    println!(
        "    evaluated {} inferences in {:.1}s ({:.0} img/s across configs)",
        33 * ds.len(),
        eval_wall.as_secs_f64(),
        (33 * ds.len()) as f64 / eval_wall.as_secs_f64()
    );
    // cross-check against the python sweep
    if let Ok(sweep) = AccuracyTable::load(&dir.join("accuracy_sweep.json")) {
        let max_diff = configs
            .iter()
            .map(|&c| (accs[c.index()] - sweep.get(c)).abs())
            .fold(0.0, f64::max);
        println!("    python-sweep cross-check: max |diff| = {max_diff:.2e} (must be 0)");
        assert!(max_diff < 1e-9, "rust/python accuracy divergence");
    }
    // cycle-accurate + PJRT spot checks
    let mut sim = DatapathSim::new(&net, Config::MAX_APPROX);
    let slow_ok = ds.features[..200]
        .iter()
        .all(|x| sim.run_image(x) == net.forward(x, Config::MAX_APPROX));
    println!("    cycle-accurate parity on 200 images: {slow_ok}");
    match ecmac::runtime::Engine::load(&dir) {
        Ok(engine) => {
            let out = engine.execute(&ds.features[..256], Config::new(17).unwrap())?;
            let native: Vec<u8> = ds.features[..256]
                .iter()
                .map(|x| net.forward(x, Config::new(17).unwrap()).pred)
                .collect();
            println!("    PJRT parity on 256 images: {}\n", out.preds == native);
        }
        Err(e) => println!("    PJRT unavailable: {e}\n"),
    }

    // ------------------------------------------------------------ 3
    println!("[3] power sweep (netlist activity on real operand traces)");
    struct Tracer {
        traces: Vec<Vec<(u32, u32)>>,
    }
    impl MacObserver for Tracer {
        fn on_mac(&mut self, neuron: usize, x: u8, w: u8) {
            self.traces[neuron].push(((x & 0x7F) as u32, (w & 0x7F) as u32));
        }
    }
    let mut tracer = Tracer {
        traces: vec![Vec::new(); 10],
    };
    let mut tsim = DatapathSim::new(&net, Config::ACCURATE);
    for x in ds.features.iter().take(64) {
        tsim.run_image_observed(x, &mut tracer);
    }
    let profile = MultiplierEnergyProfile::measure_traces(&tracer.traces);
    let raw_saving = profile.saving(profile.max_saving_config());
    let pm = PowerModel::calibrate(profile)?;
    let b0 = pm.breakdown(Config::ACCURATE);
    let worst_cfg = pm.profile().max_saving_config();
    let bw = pm.breakdown(worst_cfg);
    let sweep = pm.sweep();
    let avg_saving =
        sweep[1..].iter().map(|b| b.network_saving_pct).sum::<f64>() / 32.0;
    println!(
        "    accurate {:.3} mW   worst({worst_cfg}) {:.3} mW   (paper 5.55 / 4.81)",
        b0.total_mw, bw.total_mw
    );
    println!(
        "    max saving: network {:.2}%  neuron {:.2}%  MAC {:.2}%  (paper 13.33 / 24.78 / 44.36)",
        bw.network_saving_pct, bw.neuron_saving_pct, bw.mac_saving_pct
    );
    println!(
        "    avg network saving over 32 configs: {:.2}% (paper reports 5.84%; see DESIGN.md §Paper-Deltas)",
        avg_saving
    );
    println!(
        "    raw gate-level multiplier switching saving at worst config: {:.1}%\n",
        raw_saving * 100.0
    );

    // ------------------------------------------------------------ 4
    println!("[4] area");
    println!(
        "    {:.0} um2 vs paper 26084 um2 (ratio {:.2})\n",
        ecmac::power::area::total_area_um2(),
        ecmac::power::area::total_area_um2() / ecmac::power::area::PAPER_AREA_UM2
    );

    // ------------------------------------------------------------ 5
    println!("[5] governed serving run (power budget 5.0 mW, native backend)");
    let acc_table = AccuracyTable::load(&dir.join("accuracy_sweep.json"))?;
    let gov = Governor::new(Policy::PowerBudget { budget_mw: 5.0 }, &pm, &acc_table);
    let chosen = gov.current();
    let coord = Coordinator::start(
        CoordinatorConfig {
            max_batch: 64,
            max_wait: Duration::from_micros(200),
            queue_capacity: 8192,
            workers: 2,
            shards: 2,
            ..CoordinatorConfig::default()
        },
        Arc::new(NativeBackend {
            network: Network::new(QuantWeights::load_artifacts(&dir)?),
        }) as Arc<dyn Backend>,
        gov,
        pm,
    );
    let n = 10_000.min(ds.len());
    let t0 = Instant::now();
    let replies: Vec<_> = (0..n)
        .filter_map(|i| coord.try_submit(ds.features[i]).map(|r| (i, r)))
        .collect();
    let mut correct = 0;
    let mut answered = 0;
    for (i, r) in replies {
        if let Some(resp) = r.recv() {
            answered += 1;
            if resp.pred == ds.labels[i] {
                correct += 1;
            }
        }
    }
    let wall = t0.elapsed();
    let m = coord.shutdown();
    println!(
        "    config {chosen}; answered {answered}/{n}; accuracy {:.2}%",
        correct as f64 / answered.max(1) as f64 * 100.0
    );
    println!(
        "    throughput {:.0} img/s; latency p50 {} us p99 {} us; mean batch {:.1}; \
         modeled energy {:.3} mJ",
        answered as f64 / wall.as_secs_f64(),
        m.p50_latency_us,
        m.p99_latency_us,
        m.mean_batch_size,
        m.energy_mj
    );
    println!(
        "    (hardware at 100 MHz would need {:.2}s for {answered} images; \
         simulator real-time factor {:.1}x)",
        answered as f64 * 220.0 / 100.0e6,
        (answered as f64 * 220.0 / 100.0e6) / wall.as_secs_f64()
    );

    println!("\ntotal wall time: {:.1}s", t_start.elapsed().as_secs_f64());
    println!("=== end-to-end validation complete ===");
    Ok(())
}

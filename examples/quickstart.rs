//! Quickstart: load the artifacts, classify a handful of test images on
//! every execution path, and show the power knob doing its job.
//!
//! Run:  cargo run --release --example quickstart
//! (artifacts must exist: `make artifacts`)

use ecmac::amul::{Config, ConfigSchedule};
use ecmac::dataset::Dataset;
use ecmac::datapath::{DatapathSim, Network};
use ecmac::power::PowerModel;
use ecmac::weights::QuantWeights;

fn main() -> anyhow::Result<()> {
    let dir = ecmac::runtime::default_artifacts_dir();
    println!("loading artifacts from {}", dir.display());
    let ds = Dataset::load_test(&dir)?;
    let net = Network::new(QuantWeights::load_artifacts(&dir)?);

    // 1. classify a few images in accurate mode (native bit-exact model)
    println!("\n-- native functional path (accurate mode) --");
    for i in 0..5 {
        let r = net.forward(&ds.features[i], Config::ACCURATE);
        println!(
            "image {i}: label {} -> pred {} {}",
            ds.labels[i],
            r.pred,
            if r.pred == ds.labels[i] { "ok" } else { "WRONG" }
        );
    }

    // 2. same image through the cycle-accurate datapath
    println!("\n-- cycle-accurate datapath (5-state FSM, 10 physical neurons) --");
    let mut sim = DatapathSim::new(&net, Config::ACCURATE);
    let r = sim.run_image(&ds.features[0]);
    println!(
        "image 0: pred {} in {} cycles ({:.2} us at 100 MHz), {} MACs",
        r.pred,
        sim.stats.cycles,
        sim.stats.cycles as f64 / 100.0,
        sim.stats.mac_ops
    );

    // 3. the power knob: accuracy vs power across three configurations
    println!("\n-- the dynamic power knob --");
    let pm = PowerModel::calibrate_synthetic()?;
    let n = 2000.min(ds.len());
    for cfg_i in [0u32, 16, 32] {
        let cfg = Config::new(cfg_i).unwrap();
        let acc = net.accuracy(&ds.features[..n], &ds.labels[..n], cfg);
        let b = pm.breakdown(cfg);
        println!(
            "{cfg:<16} accuracy {:.2}%   power {:.3} mW ({}{:.2}% vs accurate)",
            acc * 100.0,
            b.total_mw,
            if b.network_saving_pct > 0.0 { "-" } else { "" },
            b.network_saving_pct
        );
    }

    // 4. per-layer schedules through the batched layer-major path: keep
    // the output layer accurate, approximate the cycle-dominant hidden
    // layer (see `ecmac topo` for arbitrary topologies)
    println!("\n-- per-layer schedule (batched layer-major path) --");
    let sched = ConfigSchedule::per_layer(vec![Config::MAX_APPROX, Config::ACCURATE]);
    let results = net.forward_batch(&ds.features[..n], &sched);
    let correct = results
        .iter()
        .zip(&ds.labels[..n])
        .filter(|(r, &y)| r.pred == y)
        .count();
    println!(
        "{sched:<16} accuracy {:.2}%   power {:.3} mW (time-weighted)",
        correct as f64 / n as f64 * 100.0,
        pm.schedule_power_mw(net.topology(), &sched)
    );

    // 5. the AOT JAX/Pallas executable via PJRT (if built)
    println!("\n-- PJRT AOT path (JAX + Pallas lowered to HLO, loaded from rust) --");
    match ecmac::runtime::Engine::load(&dir) {
        Ok(engine) => {
            let out = engine.execute(&ds.features[..5], Config::new(16).unwrap())?;
            let native: Vec<u8> = ds.features[..5]
                .iter()
                .map(|x| net.forward(x, Config::new(16).unwrap()).pred)
                .collect();
            println!("pjrt preds   {:?}", out.preds);
            println!("native preds {:?}  (bit-identical: {})", native, out.preds == native);
        }
        Err(e) => println!("engine unavailable: {e}"),
    }
    Ok(())
}

//! Regenerates the paper's evaluation artifacts: Table I and Figures
//! 5, 6, 7, plus the area roll-up.  Writes CSVs next to the artifacts
//! so the report tooling (python/tools/plot_figures.py) can render
//! publication-style plots.
//!
//! Run:  cargo run --release --example power_sweep

use ecmac::amul::metrics;
use ecmac::coordinator::governor::AccuracyTable;
use ecmac::power::{MultiplierEnergyProfile, PowerModel};
use ecmac::report;

fn main() -> anyhow::Result<()> {
    let dir = ecmac::runtime::default_artifacts_dir();

    // Table I — exhaustive multiplier error statistics
    let stats = metrics::full_table();
    let summary = metrics::table_i(&stats);
    println!("{}", report::table_i(&stats, &summary));

    // power model calibrated on real operand traces when available
    let pm = match trace_profile(&dir, 64) {
        Some(profile) => PowerModel::calibrate(profile)?,
        None => {
            eprintln!("(artifacts missing; synthetic operand stream)");
            PowerModel::calibrate(MultiplierEnergyProfile::measure_synthetic(4000, 0xD1E5E1))?
        }
    };
    let sweep = pm.sweep();
    let acc = AccuracyTable::load(&dir.join("accuracy_sweep.json"))
        .map(|t| t.accuracy)
        .unwrap_or_else(|_| vec![f64::NAN; ecmac::amul::N_CONFIGS]);

    println!("{}", report::fig5_power_improvement(&sweep));
    println!("{}", report::fig6_power_accuracy(&sweep, &acc));
    println!("{}", report::fig7_tradeoff(&sweep, &acc));
    println!("{}", report::area_table());

    // CSV outputs for plotting
    if dir.exists() {
        let mut t = report::TextTable::new(&["cfg", "er_pct", "mred_pct", "nmed_pct"]);
        for s in &stats {
            t.row(vec![
                s.cfg.to_string(),
                format!("{:.6}", s.er_pct),
                format!("{:.6}", s.mred_pct),
                format!("{:.6}", s.nmed_pct),
            ]);
        }
        std::fs::write(dir.join("table1.csv"), t.to_csv())?;
        std::fs::write(dir.join("power_sweep.csv"), report::sweep_csv(&sweep, &acc, &pm))?;
        println!(
            "wrote {} and {}",
            dir.join("table1.csv").display(),
            dir.join("power_sweep.csv").display()
        );
    }
    Ok(())
}

/// Measure the multiplier energy profile on operand traces captured from
/// the cycle-accurate datapath on real test images.
fn trace_profile(
    dir: &std::path::Path,
    images: usize,
) -> Option<MultiplierEnergyProfile> {
    use ecmac::amul::Config;
    use ecmac::datapath::{DatapathSim, MacObserver, Network};
    let ds = ecmac::dataset::Dataset::load_test(dir).ok()?;
    let net = Network::new(ecmac::weights::QuantWeights::load_artifacts(dir).ok()?);
    struct Tracer {
        traces: Vec<Vec<(u32, u32)>>,
    }
    impl MacObserver for Tracer {
        fn on_mac(&mut self, neuron: usize, x: u8, w: u8) {
            self.traces[neuron].push(((x & 0x7F) as u32, (w & 0x7F) as u32));
        }
    }
    let mut tracer = Tracer {
        traces: vec![Vec::new(); 10],
    };
    let mut sim = DatapathSim::new(&net, Config::ACCURATE);
    for x in ds.features.iter().take(images) {
        sim.run_image_observed(x, &mut tracer);
    }
    Some(MultiplierEnergyProfile::measure_traces(&tracer.traces))
}

//! Dynamic power control in action: a battery-constrained edge device
//! serving a bursty classification workload.
//!
//! The scenario: the accelerator has an energy budget that is *not*
//! enough to run every image in accurate mode.  The energy-budget
//! governor tracks consumption and walks the accuracy/power frontier so
//! the battery lasts the whole workload — the paper's knob, closed-loop.
//! A fixed-accurate baseline runs out of budget early; the governed run
//! finishes the workload with a tiny accuracy sacrifice.
//!
//! Run:  cargo run --release --example dynamic_governor

use ecmac::amul::Config;
use ecmac::coordinator::governor::{AccuracyTable, Governor, Policy};
use ecmac::coordinator::{Backend, Coordinator, CoordinatorConfig, NativeBackend};
use ecmac::dataset::Dataset;
use ecmac::datapath::Network;
use ecmac::power::PowerModel;
use ecmac::weights::QuantWeights;
use std::sync::Arc;
use std::time::Duration;

const WORKLOAD: usize = 20_000;

fn main() -> anyhow::Result<()> {
    let dir = ecmac::runtime::default_artifacts_dir();
    let ds = Dataset::load_test(&dir)?;
    let pm = PowerModel::calibrate_synthetic()?;
    let acc_table = AccuracyTable::load(&dir.join("accuracy_sweep.json"))?;

    // budget: 94% of what accurate mode would need for the workload
    let topo = QuantWeights::load_artifacts(&dir)?.topology;
    let e_accurate_mj = pm.energy_per_image_nj(&topo, Config::ACCURATE) * 1e-6;
    let budget_mj = e_accurate_mj * WORKLOAD as f64 * 0.94;
    println!(
        "workload: {WORKLOAD} images; budget {budget_mj:.3} mJ \
         (accurate mode would need {:.3} mJ)",
        e_accurate_mj * WORKLOAD as f64
    );

    // --- baseline: pinned accurate mode, stop when the battery dies ---
    let (done_fixed, acc_fixed) = run(
        &dir,
        &ds,
        &pm,
        &acc_table,
        Policy::Fixed(Config::ACCURATE),
        budget_mj,
    )?;
    println!(
        "\nbaseline (pinned accurate): served {done_fixed}/{WORKLOAD} images \
         before the budget died; accuracy {:.2}%",
        acc_fixed * 100.0
    );

    // --- governed: energy-budget policy over the same battery ---
    let (done_gov, acc_gov) = run(
        &dir,
        &ds,
        &pm,
        &acc_table,
        Policy::EnergyBudget {
            budget_mj,
            horizon_images: WORKLOAD as u64,
        },
        budget_mj,
    )?;
    println!(
        "governed (energy budget):   served {done_gov}/{WORKLOAD} images; \
         accuracy {:.2}%",
        acc_gov * 100.0
    );

    println!(
        "\n=> dynamic power control served {} more images for {:.2} accuracy \
         points — the paper's trade-off, closed-loop.",
        done_gov.saturating_sub(done_fixed),
        (acc_fixed - acc_gov) * 100.0
    );
    Ok(())
}

/// Serve the workload until finished or the battery is drained; returns
/// (images served, accuracy among served).
fn run(
    dir: &std::path::Path,
    ds: &Dataset,
    pm: &PowerModel,
    acc_table: &AccuracyTable,
    policy: Policy,
    budget_mj: f64,
) -> anyhow::Result<(usize, f64)> {
    let net = Network::new(QuantWeights::load_artifacts(dir)?);
    let gov = Governor::new(policy.clone(), pm, acc_table);
    let coord = Coordinator::start(
        CoordinatorConfig {
            max_batch: 64,
            max_wait: Duration::from_micros(100),
            queue_capacity: 8192,
            workers: 2,
            shards: 2,
            ..CoordinatorConfig::default()
        },
        Arc::new(NativeBackend { network: net }) as Arc<dyn Backend>,
        gov,
        pm.clone(),
    );
    let mut served = 0usize;
    let mut correct = 0usize;
    let mut batch_replies = Vec::new();
    'outer: for chunk_start in (0..WORKLOAD).step_by(512) {
        batch_replies.clear();
        let end = (chunk_start + 512).min(WORKLOAD);
        for i in chunk_start..end {
            let idx = i % ds.len();
            if let Some(r) = coord.try_submit(ds.features[idx]) {
                batch_replies.push((idx, r));
            }
        }
        for (idx, r) in batch_replies.drain(..) {
            if let Some(resp) = r.recv() {
                served += 1;
                if resp.pred == ds.labels[idx] {
                    correct += 1;
                }
            }
        }
        // battery check (the device's hard constraint)
        if coord.metrics().energy_mj >= budget_mj {
            break 'outer;
        }
    }
    let decisions = coord.decisions();
    let m = coord.shutdown();
    if decisions.len() > 1 {
        println!(
            "  governor walked {} configs: {:?}",
            decisions.len(),
            decisions
                .iter()
                .map(|(at, c)| format!("@{at}->{c}"))
                .collect::<Vec<_>>()
        );
    }
    println!(
        "  energy used {:.3} mJ of {budget_mj:.3} mJ; per-config counts: {:?}",
        m.energy_mj,
        m.per_cfg
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .collect::<Vec<_>>()
    );
    Ok((served, correct as f64 / served.max(1) as f64))
}
